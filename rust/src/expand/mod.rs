//! ExPAND: the paper's expander-driven prefetcher, assembled from the
//! reflector (host RC), decider (SSD controller), topology-aware
//! timeliness model, timing predictor and behavior classifier.

pub mod classifier;
pub mod decider;
pub mod reflector;
pub mod timeliness;
pub mod timing;
pub mod tokenize;

use crate::config::ExpandConfig;
use crate::prefetch::{PrefetchEnv, PrefetchFill, PrefetchIssueStats, Prefetcher};
use crate::runtime::AddressPredictor;
use crate::sim::time::{ns, Ps};
use crate::workloads::Access;
use decider::Decider;
use reflector::Reflector;
use std::cell::RefCell;
use std::rc::Rc;
use timeliness::DeadlineModel;

/// The full ExPAND prefetcher (implements the common [`Prefetcher`]
/// interface so the runner treats it like any other policy, while the
/// reflector/decider split keeps the paper's host/EP division visible).
pub struct ExpandPrefetcher {
    pub reflector: Reflector,
    pub decider: Decider,
    /// Sampling for CXL.io hit notifications (1 = every hit).
    hit_notify_stride: usize,
    hits_seen: usize,
    stats: PrefetchIssueStats,
}

impl ExpandPrefetcher {
    pub fn new(
        predictor: Rc<RefCell<dyn AddressPredictor>>,
        cfg: &ExpandConfig,
        deadline: DeadlineModel,
    ) -> Self {
        // RC-side buffer hit costs roughly an LLC-miss-to-RC traversal.
        let reflector = Reflector::new(cfg.reflector_bytes, ns(40.0));
        let decider = Decider::new(
            predictor,
            cfg.predict_stride,
            cfg.timing_entries,
            deadline,
            cfg.online_tuning,
        );
        ExpandPrefetcher {
            reflector,
            decider,
            hit_notify_stride: 4,
            hits_seen: 0,
            stats: PrefetchIssueStats::default(),
        }
    }
}

impl Prefetcher for ExpandPrefetcher {
    fn on_llc_access(
        &mut self,
        a: &Access,
        hit: bool,
        now: Ps,
        _lookahead: &[Access],
        env: &mut PrefetchEnv,
    ) -> Vec<PrefetchFill> {
        if hit {
            // Reflector reports host-side hits to the decider over
            // CXL.io (sampled to bound notification traffic). The decider
            // uses the notifications to advance its stream-consumption
            // estimate and keep pushing the frontier.
            self.hits_seen += 1;
            if self.hits_seen % self.hit_notify_stride == 0 {
                let delay = env.fabric.io_notify(env.ssd_node, now);
                let pushes = self.decider.on_host_hit(
                    self.hit_notify_stride,
                    now + delay,
                    env.ssd,
                    env.fabric,
                    env.ssd_node,
                );
                self.stats.issued += pushes.len() as u64;
                return pushes
                    .into_iter()
                    .map(|p| PrefetchFill {
                        line: p.line,
                        arrives_at: p.arrives_at,
                        to_reflector: true,
                    })
                    .collect();
            }
            return Vec::new();
        }
        // LLC miss: the reflector piggybacks the PC via MemRdPC; the
        // decider observes it at the device after the downward traversal.
        let down = env.fabric.path_latency(env.ssd_node, 24);
        let pushes =
            self.decider
                .on_memrd_pc(a.line, a.pc, now + down, env.ssd, env.fabric, env.ssd_node);
        self.stats.issued += pushes.len() as u64;
        self.stats.inferences = self.decider.stats.inferences;
        pushes
            .into_iter()
            .map(|p| PrefetchFill { line: p.line, arrives_at: p.arrives_at, to_reflector: true })
            .collect()
    }

    fn reflector_check(&mut self, line: u64, _now: Ps) -> Option<Ps> {
        self.reflector.check(line)
    }

    fn on_reflector_fill(&mut self, line: u64, _now: Ps) {
        self.reflector.insert(line);
    }

    fn name(&self) -> String {
        "ExPAND".into()
    }

    fn storage_bytes(&self) -> u64 {
        // Host side: 16 KB reflector. EP side: model + decider metadata.
        self.reflector.capacity_lines() as u64 * 64
            + self.decider.predictor_bytes()
            + self.decider.metadata_bytes()
    }

    fn issue_stats(&self) -> PrefetchIssueStats {
        self.stats
    }

    fn inference_ps(&self) -> Ps {
        self.decider.inference_ps()
    }

    fn debug_stats(&self) -> String {
        let d = &self.decider.stats;
        let r = &self.reflector.stats;
        format!(
            "decider: obs={} inf={} pushes={} dropped={} oov={} chg={} | reflector: ins={} hit={} miss={} evict-unused={}",
            d.observations, d.inferences, d.pushes, d.dropped, d.oov_stops,
            d.behavior_changes, r.inserts, r.hits, r.misses, r.dropped_unused
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Backing, CxlConfig, SsdConfig};
    use crate::cxl::configspace::ConfigSpace;
    use crate::cxl::{Fabric, Topology};
    use crate::mem::DramModel;
    use crate::runtime::MockPredictor;
    use crate::ssd::CxlSsd;

    fn build() -> (ExpandPrefetcher, Fabric, CxlSsd, DramModel, crate::cxl::NodeId) {
        let topo = Topology::chain(1);
        let dev = topo.ssds()[0];
        let fabric = Fabric::new(topo, &CxlConfig::default());
        let ssd = CxlSsd::new(&SsdConfig::default());
        let dram = DramModel::new(&crate::config::DramConfig::default());
        let mut cs = ConfigSpace::endpoint(1);
        cs.write_e2e_latency(400_000);
        let dm = DeadlineModel::new(&cs, 50_000, 1.0, 3);
        let pred = Rc::new(RefCell::new(MockPredictor::new(MockPredictor::default_shape())));
        let p = ExpandPrefetcher::new(pred, &ExpandConfig::default(), dm);
        (p, fabric, ssd, dram, dev)
    }

    #[test]
    fn misses_produce_reflector_fills_on_stride() {
        let (mut p, mut fabric, mut ssd, mut dram, dev) = build();
        let mut env = PrefetchEnv {
            fabric: &mut fabric,
            ssd: &mut ssd,
            ssd_node: dev,
            dram: &mut dram,
            backing: Backing::CxlSsd,
        };
        let mut fills = Vec::new();
        for i in 0..200u64 {
            let a = Access {
                pc: 0x77,
                line: 9000 + i,
                write: false,
                inst_gap: 5,
                dependent: false,
            };
            fills.extend(p.on_llc_access(&a, false, i * 3_000_000, &[], &mut env));
        }
        assert!(!fills.is_empty());
        assert!(fills.iter().all(|f| f.to_reflector), "ExPAND fills the reflector");
    }

    #[test]
    fn reflector_roundtrip_through_trait() {
        let (mut p, ..) = build();
        p.on_reflector_fill(555, 0);
        assert!(p.reflector.contains(555));
        let lat = p.reflector_check(555, 0);
        assert!(lat.is_some());
        assert!(p.reflector_check(555, 0).is_none(), "consumed");
    }

    #[test]
    fn storage_includes_reflector_and_model() {
        let (p, ..) = build();
        assert!(p.storage_bytes() >= 16 << 10);
    }
}
