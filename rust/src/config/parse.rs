//! TOML-subset parser for config files (offline substitute for `toml`).
//!
//! Supported grammar (sufficient for simulator configs):
//!   [section]
//!   key = value       # ints, floats, booleans, "strings"
//!   # comments, blank lines
//!
//! Values are passed verbatim to [`SimConfig::apply`], which owns typing.

use super::SimConfig;

/// Parse config text and apply it onto `cfg`.
pub fn apply_str(cfg: &mut SimConfig, text: &str) -> anyhow::Result<()> {
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated [section]", lineno + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        if section.is_empty() {
            anyhow::bail!("line {}: key outside of [section]", lineno + 1);
        }
        cfg.apply(&section, k.trim(), v.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
    }
    Ok(())
}

/// Load a config file onto `cfg`.
pub fn apply_file(cfg: &mut SimConfig, path: &str) -> anyhow::Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading config {path}: {e}"))?;
    apply_str(cfg, &text)
}

/// Apply a `section.key=value` CLI override.
pub fn apply_override(cfg: &mut SimConfig, spec: &str) -> anyhow::Result<()> {
    let (path, value) = spec
        .split_once('=')
        .ok_or_else(|| anyhow::anyhow!("--set expects section.key=value, got {spec:?}"))?;
    let (section, key) = path
        .split_once('.')
        .ok_or_else(|| anyhow::anyhow!("--set expects section.key=value, got {spec:?}"))?;
    cfg.apply(section.trim(), key.trim(), value.trim())
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MediaKind, PrefetcherKind};

    #[test]
    fn parses_full_file() {
        let text = r#"
# paper topology sweep
[cxl]
switch_levels = 4
switch_latency_ns = 200.0

[ssd]
media = "pmem"   # ExPAND-P

[sim]
prefetcher = expand
accesses = 500000
"#;
        let mut cfg = SimConfig::default();
        apply_str(&mut cfg, text).unwrap();
        assert_eq!(cfg.cxl.switch_levels, 4);
        assert_eq!(cfg.cxl.switch_latency_ns, 200.0);
        assert_eq!(cfg.ssd.media, MediaKind::Pmem);
        assert_eq!(cfg.prefetcher, PrefetcherKind::Expand);
        assert_eq!(cfg.accesses, 500_000);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let mut cfg = SimConfig::default();
        let err = apply_str(&mut cfg, "[cpu]\ncores = twelve\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        let err2 = apply_str(&mut cfg, "cores = 2\n").unwrap_err();
        assert!(err2.to_string().contains("outside"), "{err2}");
    }

    #[test]
    fn cli_override() {
        let mut cfg = SimConfig::default();
        apply_override(&mut cfg, "cpu.mshrs=32").unwrap();
        assert_eq!(cfg.cpu.mshrs, 32);
        assert!(apply_override(&mut cfg, "nodots").is_err());
    }
}
