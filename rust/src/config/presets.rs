//! Named presets reproducing the paper's configurations (Table 1).

use super::{Backing, MediaKind, PrefetcherKind, SimConfig, SsdConfig};

/// The paper's default evaluation platform (Table 1a/1b): 12-core O3 host,
/// Z-NAND CXL-SSD behind one switch level, ExPAND prefetching.
pub fn table1_default() -> SimConfig {
    SimConfig::default()
}

/// LocalDRAM baseline: same host, all memory in local DRAM, no prefetch.
pub fn local_dram() -> SimConfig {
    let mut c = SimConfig::default();
    c.backing = Backing::LocalDram;
    c.prefetcher = PrefetcherKind::None;
    c
}

/// CXL-SSD without prefetching (the NoPrefetch normalization baseline).
pub fn no_prefetch() -> SimConfig {
    let mut c = SimConfig::default();
    c.prefetcher = PrefetcherKind::None;
    c
}

/// ExPAND-Z / ExPAND-P / ExPAND-D media variants (Fig 7).
pub fn expand_with_media(media: MediaKind) -> SimConfig {
    let mut c = SimConfig::default();
    c.prefetcher = PrefetcherKind::Expand;
    c.ssd = SsdConfig::with_media(media);
    c
}

/// Fast preset for CI / smoke tests: small LLC + short traces so
/// working sets still exceed the LLC and the miss path is exercised.
pub fn smoke() -> SimConfig {
    let mut c = SimConfig::default();
    c.hierarchy.llc.size_bytes = 2 << 20;
    c.hierarchy.l2.size_bytes = 256 << 10;
    c.accesses = 100_000;
    c
}

/// Resolve a preset by name (CLI `--preset`).
pub fn by_name(name: &str) -> anyhow::Result<SimConfig> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "table1" | "default" => table1_default(),
        "localdram" | "local_dram" => local_dram(),
        "noprefetch" | "no_prefetch" => no_prefetch(),
        "expand-z" => expand_with_media(MediaKind::ZNand),
        "expand-p" => expand_with_media(MediaKind::Pmem),
        "expand-d" => expand_with_media(MediaKind::Dram),
        "smoke" => smoke(),
        other => anyhow::bail!("unknown preset {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ["table1", "localdram", "noprefetch", "expand-z", "expand-p", "expand-d", "smoke"] {
            by_name(name).unwrap();
        }
        assert!(by_name("bogus").is_err());
    }

    #[test]
    fn media_presets_differ() {
        let z = by_name("expand-z").unwrap();
        let d = by_name("expand-d").unwrap();
        assert!(z.ssd.media_read > d.ssd.media_read * 10);
    }
}
