//! Configuration system: typed config tree + TOML-subset file parser +
//! paper presets (Table 1a/1b).
//!
//! Every simulator component takes its parameters from [`SimConfig`]; the
//! CLI loads a base preset, optionally overlays a config file
//! (`--config sim.toml`), then applies `--set section.key=value`
//! overrides. This is the "real config system" a deployment would use.

pub mod parse;
pub mod presets;

use crate::sim::time::{cycle_ps, ns, us, Ps};

/// Which medium backs the expander (paper: ExPAND-Z / ExPAND-P / ExPAND-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MediaKind {
    /// Z-NAND class flash: tRd 3 us, tWr 100 us (Table 1b).
    ZNand,
    /// PMEM class SCM (Intel P5800X-like): ~6x faster reads than Z-NAND.
    Pmem,
    /// DRAM backend: upper bound for expander-driven prefetching.
    Dram,
}

impl MediaKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "znand" | "z-nand" | "z" => Ok(MediaKind::ZNand),
            "pmem" | "p" => Ok(MediaKind::Pmem),
            "dram" | "d" => Ok(MediaKind::Dram),
            _ => anyhow::bail!("unknown media {s:?} (znand|pmem|dram)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MediaKind::ZNand => "znand",
            MediaKind::Pmem => "pmem",
            MediaKind::Dram => "dram",
        }
    }

    /// Relative capacity weight used by capacity-proportional address
    /// interleaving: flash packs denser than SCM, which packs denser
    /// than a DRAM expander, so a heterogeneous pool maps proportionally
    /// more of the address space onto the denser endpoints.
    pub fn capacity_weight(&self) -> u32 {
        match self {
            MediaKind::ZNand => 4,
            MediaKind::Pmem => 2,
            MediaKind::Dram => 1,
        }
    }
}

/// Shape of the CXL fabric between the root complex and the CXL-SSD
/// endpoints (`[cxl] topology = ...` / `--topology`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TopologySpec {
    /// RC -> `cxl.switch_levels` switches -> one CXL-SSD (the seed
    /// simulator's shape; `switch_levels` keeps controlling the depth).
    Chain,
    /// Balanced tree: `levels` switch tiers of `fanout` DSPs each, with
    /// `ssds` endpoints round-robined across the leaf tier.
    Tree { levels: usize, fanout: usize, ssds: usize },
    /// Custom nested tree, e.g. `(x,s(x,x),s(s(z,p)))`: `s(...)` is a
    /// switch, `x`/`z`/`p`/`d` are endpoints (`x` = config-default media,
    /// the letters force Z-NAND / PMEM / DRAM). See
    /// [`crate::cxl::Topology::parse_custom`].
    Custom(String),
}

impl TopologySpec {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let t = s.trim();
        if t.eq_ignore_ascii_case("chain") {
            return Ok(TopologySpec::Chain);
        }
        if let Some(rest) = t.strip_prefix("tree:") {
            let parts: Vec<&str> = rest.split(',').collect();
            anyhow::ensure!(
                parts.len() == 3,
                "tree topology is tree:<levels>,<fanout>,<ssds>, got {s:?}"
            );
            let num = |i: usize, what: &str| -> anyhow::Result<usize> {
                parts[i]
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad {what} in topology {s:?}"))
            };
            let (levels, fanout, ssds) = (num(0, "levels")?, num(1, "fanout")?, num(2, "ssds")?);
            anyhow::ensure!(fanout >= 1 && ssds >= 1, "tree topology needs fanout/ssds >= 1");
            return Ok(TopologySpec::Tree { levels, fanout, ssds });
        }
        if t.starts_with('(') {
            // Validate eagerly so config errors surface at parse time.
            crate::cxl::topology::Topology::parse_custom(t)?;
            return Ok(TopologySpec::Custom(t.to_string()));
        }
        anyhow::bail!(
            "unknown topology {s:?} (chain | tree:<levels>,<fanout>,<ssds> | (s(x,..),..))"
        )
    }

    /// Compact render for `config show` and logs.
    pub fn describe(&self) -> String {
        match self {
            TopologySpec::Chain => "chain".to_string(),
            TopologySpec::Tree { levels, fanout, ssds } => {
                format!("tree:{levels},{fanout},{ssds}")
            }
            TopologySpec::Custom(s) => s.clone(),
        }
    }
}

/// How the host physical address space is distributed across the pool's
/// endpoints (`[cxl] interleave = ...` / `--interleave`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InterleavePolicy {
    /// Consecutive 64 B lines round-robin across endpoints (max
    /// bandwidth, destroys page locality inside each device).
    Line,
    /// Consecutive device pages round-robin across endpoints (preserves
    /// the internal DRAM cache's page locality; the default).
    Page,
    /// Page-granular striping weighted by each endpoint's media capacity
    /// ([`MediaKind::capacity_weight`]); equals `Page` for homogeneous
    /// pools.
    Capacity,
}

impl InterleavePolicy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "line" | "cacheline" => Ok(InterleavePolicy::Line),
            "page" => Ok(InterleavePolicy::Page),
            "capacity" | "cap" => Ok(InterleavePolicy::Capacity),
            _ => anyhow::bail!("unknown interleave policy {s:?} (line|page|capacity)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            InterleavePolicy::Line => "line",
            InterleavePolicy::Page => "page",
            InterleavePolicy::Capacity => "capacity",
        }
    }
}

/// CPU core + ROB model (Table 1a: O3 12 cores @ 3.6 GHz, 512-entry ROB).
#[derive(Debug, Clone)]
pub struct CpuConfig {
    pub cores: usize,
    pub freq_ghz: f64,
    pub rob_entries: usize,
    /// Sustained non-memory IPC used by the interval core model.
    pub base_ipc: f64,
    /// Max outstanding LLC misses (MSHRs) per core.
    pub mshrs: usize,
}

impl CpuConfig {
    pub fn cycle_ps(&self) -> Ps {
        cycle_ps(self.freq_ghz)
    }

    /// Latency the ROB can hide for one isolated miss: the time to fill
    /// the reorder window behind it.
    pub fn rob_hide_ps(&self) -> Ps {
        ((self.rob_entries as f64 / self.base_ipc) * self.cycle_ps() as f64) as Ps
    }
}

impl Default for CpuConfig {
    fn default() -> Self {
        CpuConfig { cores: 12, freq_ghz: 3.6, rob_entries: 512, base_ipc: 2.0, mshrs: 16 }
    }
}

/// One cache level (sizes/latencies from Table 1a).
#[derive(Debug, Clone)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub ways: usize,
    pub latency_cycles: u64,
    pub line_bytes: usize,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        (self.size_bytes / self.line_bytes / self.ways).max(1)
    }
}

/// The three-level hierarchy. The paper's Table 1a gives L1I 32K/2w/5c,
/// L1D 48K/2w/5c, L2 1.25M/16w/20c; the LLC row is garbled in the text, so
/// we use a 2.5 MB/core x 12 shared LLC (30 MB, 15-way, 40 cycles) — the
/// Sapphire-Rapids-class value consistent with the 12-core O3 host.
/// `llc_scale` shrinks LLC + working sets together for fast runs.
#[derive(Debug, Clone)]
pub struct HierarchyConfig {
    pub l1d: CacheConfig,
    pub l2: CacheConfig,
    pub llc: CacheConfig,
}

impl Default for HierarchyConfig {
    fn default() -> Self {
        HierarchyConfig {
            l1d: CacheConfig { size_bytes: 48 << 10, ways: 2, latency_cycles: 5, line_bytes: 64 },
            l2: CacheConfig {
                size_bytes: 1_280 << 10,
                ways: 16,
                latency_cycles: 20,
                line_bytes: 64,
            },
            llc: CacheConfig {
                size_bytes: 30 << 20,
                ways: 15,
                latency_cycles: 40,
                line_bytes: 64,
            },
        }
    }
}

/// Host-local DRAM (Table 1a: tRP=tRCD=tCAS=22ns, 8 rank, 16 bank, 2 ch).
#[derive(Debug, Clone)]
pub struct DramConfig {
    pub t_rp_ns: f64,
    pub t_rcd_ns: f64,
    pub t_cas_ns: f64,
    pub channels: usize,
    pub banks_per_channel: usize,
    /// Data burst transfer time per 64B line.
    pub burst_ns: f64,
}

impl DramConfig {
    /// Closed-row access latency (row activate + column read + burst).
    pub fn miss_latency(&self) -> Ps {
        ns(self.t_rp_ns + self.t_rcd_ns + self.t_cas_ns + self.burst_ns)
    }

    /// Open-row hit latency (column read + burst).
    pub fn hit_latency(&self) -> Ps {
        ns(self.t_cas_ns + self.burst_ns)
    }
}

impl Default for DramConfig {
    fn default() -> Self {
        DramConfig {
            t_rp_ns: 22.0,
            t_rcd_ns: 22.0,
            t_cas_ns: 22.0,
            channels: 2,
            banks_per_channel: 16 * 8,
            burst_ns: 4.0,
        }
    }
}

/// CXL link + switch model (Table 1a: PCIe 6.0 64 GT/s, CXL 3.0).
#[derive(Debug, Clone)]
pub struct CxlConfig {
    /// Link speed per lane, GT/s.
    pub gts: f64,
    /// Lane count per link.
    pub lanes: usize,
    /// Flit size in bytes (CXL 3.0: 256B flit mode; 64B slots).
    pub flit_bytes: usize,
    /// Per-switch store-and-forward + arbitration latency (one direction).
    pub switch_latency_ns: f64,
    /// Port/PHY + retimer latency per link traversal (one direction).
    pub link_latency_ns: f64,
    /// Root-complex / home-agent processing per request.
    pub rc_latency_ns: f64,
    /// Number of switch levels between RC and the CXL-SSD (0 = direct).
    pub switch_levels: usize,
    /// Downstream fan-out used when building tree topologies.
    pub fanout: usize,
    /// Fabric shape (chain, balanced tree, or custom nested tree).
    pub topology: TopologySpec,
    /// Address-interleaving policy across the pool's endpoints.
    pub interleave: InterleavePolicy,
}

impl Default for CxlConfig {
    fn default() -> Self {
        CxlConfig {
            gts: 64.0,
            lanes: 8,
            flit_bytes: 256,
            // Measured CXL switch traversals are ~180-270 ns; we use 180.
            switch_latency_ns: 180.0,
            link_latency_ns: 25.0,
            rc_latency_ns: 40.0,
            switch_levels: 1,
            fanout: 4,
            topology: TopologySpec::Chain,
            interleave: InterleavePolicy::Page,
        }
    }
}

impl CxlConfig {
    /// Materialize the configured fabric shape.
    pub fn build_topology(&self) -> anyhow::Result<crate::cxl::Topology> {
        use crate::cxl::Topology;
        Ok(match &self.topology {
            TopologySpec::Chain => Topology::chain(self.switch_levels),
            TopologySpec::Tree { levels, fanout, ssds } => {
                Topology::tree(*levels, *fanout, *ssds)
            }
            TopologySpec::Custom(spec) => Topology::parse_custom(spec)?,
        })
    }
}

/// CXL-SSD device (Table 1b).
#[derive(Debug, Clone)]
pub struct SsdConfig {
    pub media: MediaKind,
    /// Backend media read/program latency.
    pub media_read: Ps,
    pub media_write: Ps,
    /// Independent backend channels (queuing).
    pub channels: usize,
    /// Internal DRAM cache size (Table 1b: 1.5 GB).
    pub internal_dram_bytes: usize,
    /// Internal DRAM timing (Table 1b: tRP=tRCD=9.1ns, tRAS=19ns).
    pub internal_dram_ns: f64,
    /// Internal cache page size (lines are cached in pages).
    pub page_bytes: usize,
    /// Controller firmware/datapath overhead per request.
    pub controller_ns: f64,
}

impl SsdConfig {
    pub fn with_media(media: MediaKind) -> Self {
        let (media_read, media_write) = match media {
            MediaKind::ZNand => (us(3.0), us(100.0)),
            // Paper: Z-NAND is "6x slower than PMEM".
            MediaKind::Pmem => (ns(500.0), us(2.0)),
            MediaKind::Dram => (ns(46.0), ns(46.0)),
        };
        SsdConfig {
            media,
            media_read,
            media_write,
            channels: 8,
            internal_dram_bytes: 3 << 29, // 1.5 GB
            internal_dram_ns: 9.1 + 9.1 + 4.0,
            page_bytes: 4096,
            controller_ns: 30.0,
        }
    }
}

impl Default for SsdConfig {
    fn default() -> Self {
        SsdConfig::with_media(MediaKind::ZNand)
    }
}

/// Which prefetcher drives the LLC (paper's comparison set).
#[derive(Debug, Clone, PartialEq)]
pub enum PrefetcherKind {
    None,
    /// Best-offset spatial prefetcher (Michaud, HPCA'16) — paper's Rule1.
    Rule1,
    /// Irregular-stream temporal prefetcher (ISB class) — paper's Rule2.
    Rule2,
    /// LSTM-based predictor via AOT artifact — paper's ML1.
    Ml1,
    /// Transformer-based predictor via AOT artifact — paper's ML2.
    Ml2,
    /// The paper's system: expander-driven heterogeneous predictor.
    Expand,
    /// Oracle-backed synthetic prefetcher with parameterized
    /// accuracy/coverage/timeliness (Fig 2a / Fig 4c harnesses).
    Synthetic { accuracy: f64, coverage: f64 },
}

impl PrefetcherKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "noprefetch" => PrefetcherKind::None,
            "rule1" | "best-offset" | "bo" => PrefetcherKind::Rule1,
            "rule2" | "temporal" | "isb" => PrefetcherKind::Rule2,
            "ml1" | "lstm" => PrefetcherKind::Ml1,
            "ml2" | "transformer" => PrefetcherKind::Ml2,
            "expand" => PrefetcherKind::Expand,
            other => anyhow::bail!("unknown prefetcher {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PrefetcherKind::None => "NoPrefetch",
            PrefetcherKind::Rule1 => "Rule1",
            PrefetcherKind::Rule2 => "Rule2",
            PrefetcherKind::Ml1 => "ML1",
            PrefetcherKind::Ml2 => "ML2",
            PrefetcherKind::Expand => "ExPAND",
            PrefetcherKind::Synthetic { .. } => "Synthetic",
        }
    }
}

/// ExPAND-specific knobs (reflector/decider/timeliness).
#[derive(Debug, Clone)]
pub struct ExpandConfig {
    /// Reflector RC-side buffer (paper: 16 KB).
    pub reflector_bytes: usize,
    /// Decider sliding-window length (must match the artifact's window).
    pub window: usize,
    /// Invoke the address predictor every `stride` LLC misses.
    pub predict_stride: usize,
    /// Timing-predictor history entries (paper: 80 B = 10 x 8 B).
    pub timing_entries: usize,
    /// Timeliness-model accuracy in [0,1]; 1.0 = exact (Fig 4c sweeps it).
    pub timeliness_accuracy: f64,
    /// Enable the decision-tree behavior classifier (online tuning).
    pub online_tuning: bool,
    /// Safety margin subtracted from the prefetch issue deadline.
    pub margin_ns: f64,
    /// Report every Nth reflector hit to the owning decider over CXL.io
    /// (1 = every hit; larger strides bound notification traffic).
    pub hit_notify_stride: usize,
}

impl Default for ExpandConfig {
    fn default() -> Self {
        ExpandConfig {
            reflector_bytes: 16 << 10,
            window: 32,
            predict_stride: 4,
            timing_entries: 10,
            timeliness_accuracy: 1.0,
            online_tuning: true,
            margin_ns: 500.0,
            hit_notify_stride: 4,
        }
    }
}

/// Back-invalidation coherence knobs (`[coherence]`).
#[derive(Debug, Clone)]
pub struct CoherenceConfig {
    /// BI-directory (snoop filter) entries per endpoint. Sized to cover
    /// the host LLC by default; shrinking it forces capacity evictions
    /// and the BISnp traffic they carry.
    pub dir_entries: usize,
    /// Directory associativity.
    pub dir_ways: usize,
    /// Inject a device-side update to a recently-demanded line every N
    /// host accesses (0 = off) — exercises BISnp invalidation and
    /// stale-push protection under load.
    pub device_update_every: usize,
    /// Run the shadow-memory consistency auditor alongside the
    /// simulation (also forced on crate-wide by `--features audit`).
    pub audit: bool,
}

impl Default for CoherenceConfig {
    fn default() -> Self {
        CoherenceConfig {
            // 1M entries x 8 B tag SRAM ~ 8 MB: covers the 30 MB LLC's
            // 491K lines plus the reflector with headroom.
            dir_entries: 1 << 20,
            dir_ways: 16,
            device_update_every: 0,
            audit: cfg!(feature = "audit"),
        }
    }
}

/// Where demand memory lives (Fig 1 / Fig 5 comparisons).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backing {
    /// Everything in host-local DRAM (the LocalDRAM baseline).
    LocalDram,
    /// Working set on the CXL-SSD behind the switch fabric.
    CxlSsd,
}

/// Top-level simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub cpu: CpuConfig,
    pub hierarchy: HierarchyConfig,
    pub dram: DramConfig,
    pub cxl: CxlConfig,
    pub ssd: SsdConfig,
    pub expand: ExpandConfig,
    pub coherence: CoherenceConfig,
    /// Deterministic fault-injection schedule (`[fault]` / `--fault`);
    /// quiet by default.
    pub fault: crate::fault::FaultConfig,
    pub prefetcher: PrefetcherKind,
    pub backing: Backing,
    /// Accesses to simulate per run (trace length).
    pub accesses: usize,
    /// RNG seed for workload generation and stochastic models.
    pub seed: u64,
    /// Directory holding AOT artifacts (HLO text + manifest).
    pub artifacts_dir: String,
    /// Host shards sharing the CXL pool (1 = classic single-host run;
    /// >1 engages the epoch-quantized multi-host engine).
    pub hosts: usize,
    /// Demand accesses per host per epoch quantum (multi-host engine).
    pub epoch_accesses: usize,
    /// Multi-host worker threads (0 = all available cores).
    pub threads: usize,
    /// Hosts per merge group in the fleet engine's hierarchical epoch
    /// merge tree (0 = auto: hosts split evenly over the workers).
    /// Purely a scheduling knob — results are bit-identical for every
    /// value (pinned by proptests).
    pub merge_group: usize,
    /// Fleet workload layer (`[fleet]` section / `--fleet`): tenant
    /// mix, arrival stagger and traffic shaping for multi-host runs.
    /// `None` leaves per-host streams unshaped.
    pub fleet: Option<crate::workloads::fleet::FleetSpec>,
    /// Hot-loop batch size: accesses pulled, routed and replayed per
    /// batch in `run_segment`. Purely a throughput knob — results are
    /// bit-identical for every value (pinned by proptests); 1 recovers
    /// the scalar per-access loop.
    pub batch: usize,
    /// Default workload spec (`[sim] workload = "pr"` or
    /// `"trace:<path>"`); the CLI positional / `--workload` overrides
    /// it. `None` means the CLI must name one.
    pub workload: Option<String>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            cpu: CpuConfig::default(),
            hierarchy: HierarchyConfig::default(),
            dram: DramConfig::default(),
            cxl: CxlConfig::default(),
            ssd: SsdConfig::default(),
            expand: ExpandConfig::default(),
            coherence: CoherenceConfig::default(),
            fault: crate::fault::FaultConfig::default(),
            prefetcher: PrefetcherKind::None,
            backing: Backing::CxlSsd,
            accesses: 2_000_000,
            seed: 0xE7A5D,
            artifacts_dir: "artifacts".to_string(),
            hosts: 1,
            epoch_accesses: 8192,
            threads: 0,
            merge_group: 0,
            fleet: None,
            batch: 256,
            workload: None,
        }
    }
}

impl SimConfig {
    /// Apply one `section.key = value` override (config file and `--set`).
    pub fn apply(&mut self, section: &str, key: &str, value: &str) -> anyhow::Result<()> {
        let v = value.trim().trim_matches('"');
        let bad = || anyhow::anyhow!("bad value {value:?} for {section}.{key}");
        macro_rules! num {
            () => {
                v.parse().map_err(|_| bad())?
            };
        }
        match (section, key) {
            ("cpu", "cores") => self.cpu.cores = num!(),
            ("cpu", "freq_ghz") => self.cpu.freq_ghz = num!(),
            ("cpu", "rob_entries") => self.cpu.rob_entries = num!(),
            ("cpu", "base_ipc") => self.cpu.base_ipc = num!(),
            ("cpu", "mshrs") => self.cpu.mshrs = num!(),
            ("llc", "size_bytes") => self.hierarchy.llc.size_bytes = num!(),
            ("llc", "ways") => self.hierarchy.llc.ways = num!(),
            ("llc", "latency_cycles") => self.hierarchy.llc.latency_cycles = num!(),
            ("l2", "size_bytes") => self.hierarchy.l2.size_bytes = num!(),
            ("l2", "ways") => self.hierarchy.l2.ways = num!(),
            ("l1d", "size_bytes") => self.hierarchy.l1d.size_bytes = num!(),
            ("dram", "channels") => self.dram.channels = num!(),
            ("dram", "t_cas_ns") => self.dram.t_cas_ns = num!(),
            ("cxl", "switch_levels") => self.cxl.switch_levels = num!(),
            ("cxl", "switch_latency_ns") => self.cxl.switch_latency_ns = num!(),
            ("cxl", "link_latency_ns") => self.cxl.link_latency_ns = num!(),
            ("cxl", "lanes") => self.cxl.lanes = num!(),
            ("cxl", "fanout") => self.cxl.fanout = num!(),
            ("cxl", "topology") => self.cxl.topology = TopologySpec::parse(v)?,
            ("cxl", "interleave") => self.cxl.interleave = InterleavePolicy::parse(v)?,
            ("ssd", "media") => self.ssd = SsdConfig::with_media(MediaKind::parse(v)?),
            ("ssd", "channels") => self.ssd.channels = num!(),
            ("ssd", "internal_dram_bytes") => self.ssd.internal_dram_bytes = num!(),
            ("ssd", "controller_ns") => self.ssd.controller_ns = num!(),
            ("expand", "reflector_bytes") => self.expand.reflector_bytes = num!(),
            ("expand", "predict_stride") => self.expand.predict_stride = num!(),
            ("expand", "timeliness_accuracy") => self.expand.timeliness_accuracy = num!(),
            ("expand", "online_tuning") => {
                self.expand.online_tuning = v.parse().map_err(|_| bad())?
            }
            ("expand", "margin_ns") => self.expand.margin_ns = num!(),
            ("expand", "hit_notify_stride") => self.expand.hit_notify_stride = num!(),
            ("coherence", "dir_entries") => self.coherence.dir_entries = num!(),
            ("coherence", "dir_ways") => self.coherence.dir_ways = num!(),
            ("coherence", "device_update_every") => self.coherence.device_update_every = num!(),
            ("coherence", "audit") => self.coherence.audit = v.parse().map_err(|_| bad())?,
            ("fault", _) => self.fault.apply(key, v)?,
            ("sim", "accesses") => self.accesses = num!(),
            ("sim", "seed") => self.seed = num!(),
            ("sim", "hosts") => self.hosts = num!(),
            ("sim", "epoch_accesses") => self.epoch_accesses = num!(),
            ("sim", "threads") => self.threads = num!(),
            ("sim", "merge_group") => self.merge_group = num!(),
            ("sim", "batch") => self.batch = num!(),
            ("fleet", _) => self
                .fleet
                .get_or_insert_with(crate::workloads::fleet::FleetSpec::default)
                .apply(key, v)?,
            ("sim", "artifacts_dir") => self.artifacts_dir = v.to_string(),
            ("sim", "workload") => {
                // Validate eagerly (bad names fail at config time, with
                // the full list of valid choices); trace paths are only
                // opened when a run starts.
                crate::workloads::WorkloadSpec::parse(v)?;
                self.workload = Some(v.to_string());
            }
            ("sim", "prefetcher") => self.prefetcher = PrefetcherKind::parse(v)?,
            ("sim", "backing") => {
                self.backing = match v {
                    "local_dram" | "localdram" => Backing::LocalDram,
                    "cxl_ssd" | "cxlssd" => Backing::CxlSsd,
                    _ => return Err(bad()),
                }
            }
            _ => anyhow::bail!("unknown config key {section}.{key}"),
        }
        Ok(())
    }

    /// Render the effective config (`expand config show`).
    pub fn render(&self) -> String {
        let mut out = format!(
            "[cpu] cores={} freq_ghz={} rob={} ipc={} mshrs={}\n\
             [l1d] {}KB/{}w {}cyc\n[l2] {}KB/{}w {}cyc\n[llc] {}MB/{}w {}cyc\n\
             [dram] tRP/tRCD/tCAS={}ns/{}ns/{}ns ch={}\n\
             [cxl] {} GT/s x{} flit={}B switch={}ns/hop link={}ns levels={} fanout={} \
             topo={} il={}\n\
             [ssd] media={} read={}ns write={}ns ch={} idram={}MB ctrl={}ns\n\
             [expand] reflector={}KB window={} stride={} timing={} tacc={} tuning={} \
             notify_stride={}\n\
             [coherence] dir_entries={} dir_ways={} device_update_every={} audit={}\n\
             [fault] {}\n\
             [sim] prefetcher={} backing={:?} accesses={} seed={:#x} hosts={} \
             epoch_accesses={} threads={} merge_group={} batch={} workload={}",
            self.cpu.cores, self.cpu.freq_ghz, self.cpu.rob_entries, self.cpu.base_ipc,
            self.cpu.mshrs,
            self.hierarchy.l1d.size_bytes >> 10, self.hierarchy.l1d.ways,
            self.hierarchy.l1d.latency_cycles,
            self.hierarchy.l2.size_bytes >> 10, self.hierarchy.l2.ways,
            self.hierarchy.l2.latency_cycles,
            self.hierarchy.llc.size_bytes >> 20, self.hierarchy.llc.ways,
            self.hierarchy.llc.latency_cycles,
            self.dram.t_rp_ns, self.dram.t_rcd_ns, self.dram.t_cas_ns, self.dram.channels,
            self.cxl.gts, self.cxl.lanes, self.cxl.flit_bytes, self.cxl.switch_latency_ns,
            self.cxl.link_latency_ns, self.cxl.switch_levels, self.cxl.fanout,
            self.cxl.topology.describe(), self.cxl.interleave.name(),
            self.ssd.media.name(), self.ssd.media_read / 1000, self.ssd.media_write / 1000,
            self.ssd.channels, self.ssd.internal_dram_bytes >> 20, self.ssd.controller_ns,
            self.expand.reflector_bytes >> 10, self.expand.window, self.expand.predict_stride,
            self.expand.timing_entries, self.expand.timeliness_accuracy,
            self.expand.online_tuning, self.expand.hit_notify_stride,
            self.coherence.dir_entries, self.coherence.dir_ways,
            self.coherence.device_update_every, self.coherence.audit,
            self.fault.render(),
            self.prefetcher.name(), self.backing, self.accesses, self.seed,
            self.hosts, self.epoch_accesses, self.threads, self.merge_group, self.batch,
            self.workload.as_deref().unwrap_or("-"),
        );
        if let Some(fleet) = &self.fleet {
            out.push('\n');
            out.push_str(fleet.render().trim_end());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = SimConfig::default();
        assert_eq!(c.cpu.cores, 12);
        assert_eq!(c.cpu.rob_entries, 512);
        assert_eq!(c.hierarchy.l2.size_bytes, 1_280 << 10);
        assert_eq!(c.ssd.media_read, 3_000_000); // 3 us in ps
        assert_eq!(c.ssd.media_write, 100_000_000); // 100 us
        assert_eq!(c.expand.reflector_bytes, 16 << 10);
        assert_eq!(c.expand.timing_entries, 10); // 80 B / 8 B
    }

    #[test]
    fn media_ratios() {
        let z = SsdConfig::with_media(MediaKind::ZNand);
        let p = SsdConfig::with_media(MediaKind::Pmem);
        assert_eq!(z.media_read / p.media_read, 6); // paper: Z 6x slower than P
    }

    #[test]
    fn apply_overrides() {
        let mut c = SimConfig::default();
        c.apply("cxl", "switch_levels", "3").unwrap();
        c.apply("ssd", "media", "pmem").unwrap();
        c.apply("sim", "prefetcher", "expand").unwrap();
        assert_eq!(c.cxl.switch_levels, 3);
        assert_eq!(c.ssd.media, MediaKind::Pmem);
        assert_eq!(c.prefetcher, PrefetcherKind::Expand);
        assert!(c.apply("nope", "x", "1").is_err());
        assert!(c.apply("cpu", "cores", "abc").is_err());
    }

    #[test]
    fn topology_spec_parses_and_applies() {
        assert_eq!(TopologySpec::parse("chain").unwrap(), TopologySpec::Chain);
        assert_eq!(
            TopologySpec::parse("tree:2,4,8").unwrap(),
            TopologySpec::Tree { levels: 2, fanout: 4, ssds: 8 }
        );
        let custom = TopologySpec::parse("(x,s(x,x))").unwrap();
        assert_eq!(custom, TopologySpec::Custom("(x,s(x,x))".to_string()));
        assert!(TopologySpec::parse("ring").is_err());
        assert!(TopologySpec::parse("tree:2,4").is_err());
        assert!(TopologySpec::parse("(q)").is_err(), "bad endpoint letter");

        let mut c = SimConfig::default();
        c.apply("cxl", "topology", "tree:1,2,4").unwrap();
        c.apply("cxl", "interleave", "line").unwrap();
        assert_eq!(c.cxl.topology, TopologySpec::Tree { levels: 1, fanout: 2, ssds: 4 });
        assert_eq!(c.cxl.interleave, InterleavePolicy::Line);
        let topo = c.cxl.build_topology().unwrap();
        assert_eq!(topo.ssds().len(), 4);
        assert!(c.render().contains("tree:1,2,4"));
    }

    #[test]
    fn default_topology_matches_seed_chain() {
        let c = SimConfig::default();
        let topo = c.cxl.build_topology().unwrap();
        let ssds = topo.ssds();
        assert_eq!(ssds.len(), 1);
        assert_eq!(topo.switch_depth(ssds[0]), c.cxl.switch_levels);
        assert_eq!(c.cxl.interleave, InterleavePolicy::Page);
    }

    #[test]
    fn expand_and_coherence_keys_apply() {
        let mut c = SimConfig::default();
        assert_eq!(c.expand.hit_notify_stride, 4, "paper default");
        c.apply("expand", "hit_notify_stride", "2").unwrap();
        c.apply("coherence", "dir_entries", "1024").unwrap();
        c.apply("coherence", "dir_ways", "4").unwrap();
        c.apply("coherence", "device_update_every", "500").unwrap();
        c.apply("coherence", "audit", "true").unwrap();
        assert_eq!(c.expand.hit_notify_stride, 2);
        assert_eq!(c.coherence.dir_entries, 1024);
        assert_eq!(c.coherence.dir_ways, 4);
        assert_eq!(c.coherence.device_update_every, 500);
        assert!(c.coherence.audit);
        assert!(c.apply("coherence", "audit", "maybe").is_err());
        assert!(c.render().contains("dir_entries=1024"));
    }

    #[test]
    fn multi_host_keys_apply_and_render() {
        let mut c = SimConfig::default();
        assert_eq!(c.hosts, 1, "single-host by default");
        assert_eq!(c.threads, 0, "auto thread count by default");
        c.apply("sim", "hosts", "4").unwrap();
        c.apply("sim", "epoch_accesses", "2048").unwrap();
        c.apply("sim", "threads", "2").unwrap();
        assert_eq!(c.hosts, 4);
        assert_eq!(c.epoch_accesses, 2048);
        assert_eq!(c.threads, 2);
        assert!(c.render().contains("hosts=4"));
        assert!(c.render().contains("epoch_accesses=2048"));
        assert!(c.apply("sim", "hosts", "abc").is_err());
    }

    #[test]
    fn fleet_keys_apply_and_render() {
        let mut c = SimConfig::default();
        assert_eq!(c.merge_group, 0, "auto merge-group sizing by default");
        assert!(c.fleet.is_none(), "no fleet layer by default");
        c.apply("sim", "merge_group", "8").unwrap();
        assert_eq!(c.merge_group, 8);
        assert!(c.render().contains("merge_group=8"));
        c.apply("fleet", "tenants", "6").unwrap();
        c.apply("fleet", "shape", "diurnal").unwrap();
        let fleet = c.fleet.as_ref().expect("fleet section materializes on first key");
        assert_eq!(fleet.tenants, 6);
        assert_eq!(fleet.shape, crate::workloads::fleet::TrafficShape::Diurnal);
        assert!(c.render().contains("[fleet]"));
        assert!(c.render().contains("shape = diurnal"));
        assert!(c.apply("fleet", "bogus", "1").is_err());
        assert!(c.apply("sim", "merge_group", "x").is_err());
    }

    #[test]
    fn batch_key_applies_and_renders() {
        let mut c = SimConfig::default();
        assert_eq!(c.batch, 256, "batched hot loop by default");
        c.apply("sim", "batch", "64").unwrap();
        assert_eq!(c.batch, 64);
        assert!(c.render().contains("batch=64"));
        assert!(c.apply("sim", "batch", "wide").is_err());
    }

    #[test]
    fn workload_key_validates_and_renders() {
        let mut c = SimConfig::default();
        assert_eq!(c.workload, None, "no default workload");
        assert!(c.render().contains("workload=-"));
        c.apply("sim", "workload", "pr").unwrap();
        assert_eq!(c.workload.as_deref(), Some("pr"));
        c.apply("sim", "workload", "trace:/tmp/run.trace").unwrap();
        assert_eq!(c.workload.as_deref(), Some("trace:/tmp/run.trace"));
        assert!(c.render().contains("workload=trace:/tmp/run.trace"));
        let err = c.apply("sim", "workload", "bogus").unwrap_err().to_string();
        assert!(err.contains("libquantum"), "lists valid names: {err}");
        assert_eq!(c.workload.as_deref(), Some("trace:/tmp/run.trace"), "bad value rejected");
    }

    #[test]
    fn fault_keys_apply_and_render() {
        let mut c = SimConfig::default();
        assert!(!c.fault.enabled(), "quiet by default");
        assert!(c.render().contains("[fault] off"));
        c.apply("fault", "link_crc", "1e-4").unwrap();
        c.apply("fault", "dev_stall", "ep1@4Kacc:100us").unwrap();
        c.apply("fault", "hot_remove", "ep2@8Kacc").unwrap();
        c.apply("fault", "poison", "1e-5").unwrap();
        c.apply("fault", "timeout", "25us").unwrap();
        assert!(c.fault.enabled());
        assert_eq!(c.fault.link_crc, 1e-4);
        assert_eq!(c.fault.dev_stall.unwrap().at, 4_000);
        assert_eq!(c.fault.hot_remove.unwrap().ep, 2);
        assert_eq!(c.fault.timeout_ps, 25_000_000);
        assert!(c.render().contains("link_crc=1e-4"));
        assert!(c.apply("fault", "link_crc", "2.0").is_err());
        assert!(c.apply("fault", "nope", "1").is_err());
    }

    #[test]
    fn capacity_weights_rank_by_density() {
        assert!(MediaKind::ZNand.capacity_weight() > MediaKind::Pmem.capacity_weight());
        assert!(MediaKind::Pmem.capacity_weight() > MediaKind::Dram.capacity_weight());
    }

    #[test]
    fn rob_hide_is_plausible() {
        let c = CpuConfig::default();
        // 512 entries / 2 IPC * 278 ps = ~71 ns
        let h = c.rob_hide_ps();
        assert!(h > 60_000 && h < 80_000, "rob hide {h} ps");
    }
}
