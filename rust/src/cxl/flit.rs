//! Flit timing math for CXL links.
//!
//! CXL.mem messages are packed into flits that serialize over the PCIe
//! PHY. We compute per-link serialization delay from the configured
//! GT/s, lane count and flit size, including the PAM4/FEC efficiency
//! factor of PCIe 6.0 flit mode.

use crate::config::CxlConfig;
use crate::sim::time::Ps;

/// Effective payload efficiency of PCIe 6.0 flit mode (FEC + CRC + DLLP
/// overhead inside the 256B flit: 242/256 usable, ~0.945).
pub const FLIT_EFFICIENCY: f64 = 0.945;

/// Link bytes/ns for a config (raw GT/s x lanes / 8 bits, derated).
pub fn link_bytes_per_ns(cfg: &CxlConfig) -> f64 {
    cfg.gts * cfg.lanes as f64 / 8.0 * FLIT_EFFICIENCY
}

/// Time to serialize `bytes` of message onto the link, rounded up to
/// whole flits (a 16B header still occupies a flit slot share; small
/// messages pack, so we charge fractional flits at slot granularity 64B).
pub fn serialize_ps(cfg: &CxlConfig, bytes: usize) -> Ps {
    let slots = bytes.div_ceil(64).max(1);
    let wire_bytes = (slots * 64) as f64;
    let ns = wire_bytes / link_bytes_per_ns(cfg);
    (ns * 1000.0).round() as Ps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcie6_x8_rates() {
        let cfg = CxlConfig::default(); // 64 GT/s x8
        let bpn = link_bytes_per_ns(&cfg);
        assert!((bpn - 60.48).abs() < 0.01, "bytes/ns {bpn}");
        // One 64B slot ≈ 1.06 ns.
        let t = serialize_ps(&cfg, 16);
        assert!((1000..1200).contains(&t), "{t} ps");
        // A 80B DRS message takes two slots.
        assert_eq!(serialize_ps(&cfg, 80), 2 * serialize_ps(&cfg, 64));
    }

    #[test]
    fn narrower_link_is_slower() {
        let mut narrow = CxlConfig::default();
        narrow.lanes = 4;
        let wide = CxlConfig::default();
        assert!(serialize_ps(&narrow, 64) > serialize_ps(&wide, 64));
    }
}
