//! CXL fabric substrate: topology, enumeration, DOE/DSLBIS, config space,
//! flit timing, CXL.mem transactions, and the queued latency model.

pub mod configspace;
pub mod doe;
pub mod enumeration;
pub mod fabric;
pub mod flit;
pub mod topology;
pub mod transaction;

pub use fabric::{Fabric, FabricPlan};
pub use topology::{NodeId, NodeKind, Topology};
