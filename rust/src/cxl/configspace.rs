//! Per-device PCIe configuration space (the slice of it ExPAND uses).
//!
//! The reflector writes the computed end-to-end latency for each CXL-SSD
//! into a designated vendor-specific (DVSEC) register of that device's
//! config space; the decider reads it back to convert predicted access
//! times into prefetch *issue* deadlines. We model the config space as a
//! sparse dword register file with the standard header fields plus the
//! ExPAND DVSEC.

use crate::sim::time::Ps;
use std::collections::BTreeMap;

/// Standard header offsets (dword-indexed).
pub const REG_VENDOR_DEVICE: u16 = 0x0;
pub const REG_CLASS: u16 = 0x2;
/// ExPAND DVSEC: end-to-end latency, low/high dwords (vendor space).
pub const REG_EXPAND_E2E_LO: u16 = 0x40;
pub const REG_EXPAND_E2E_HI: u16 = 0x41;

/// Panmnesia vendor id used by the ExPAND DVSEC in this model.
pub const VENDOR_ID: u32 = 0x1DE5;

/// A sparse 4 KB config space (dword registers).
#[derive(Debug, Clone, Default)]
pub struct ConfigSpace {
    regs: BTreeMap<u16, u32>,
}

impl ConfigSpace {
    /// Endpoint config space with the standard identification header.
    pub fn endpoint(device_id: u16) -> Self {
        let mut cs = ConfigSpace::default();
        cs.write(REG_VENDOR_DEVICE, (u32::from(device_id) << 16) | VENDOR_ID);
        cs.write(REG_CLASS, 0x0502_0000); // memory controller / CXL
        cs
    }

    pub fn read(&self, reg: u16) -> u32 {
        *self.regs.get(&reg).unwrap_or(&0)
    }

    pub fn write(&mut self, reg: u16, value: u32) {
        self.regs.insert(reg, value);
    }

    /// Reflector-side: publish the end-to-end latency (ps) to the device.
    pub fn write_e2e_latency(&mut self, e2e: Ps) {
        self.write(REG_EXPAND_E2E_LO, (e2e & 0xFFFF_FFFF) as u32);
        self.write(REG_EXPAND_E2E_HI, (e2e >> 32) as u32);
    }

    /// Decider-side: read the published end-to-end latency (ps).
    pub fn read_e2e_latency(&self) -> Ps {
        (u64::from(self.read(REG_EXPAND_E2E_HI)) << 32)
            | u64::from(self.read(REG_EXPAND_E2E_LO))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2e_roundtrip_64bit() {
        let mut cs = ConfigSpace::endpoint(0xE7);
        let lat: Ps = 5_000_000_123; // > 32 bits
        cs.write_e2e_latency(lat);
        assert_eq!(cs.read_e2e_latency(), lat);
    }

    #[test]
    fn header_identifies_vendor() {
        let cs = ConfigSpace::endpoint(0xE7);
        assert_eq!(cs.read(REG_VENDOR_DEVICE) & 0xFFFF, VENDOR_ID);
        assert_eq!(cs.read(REG_VENDOR_DEVICE) >> 16, 0xE7);
    }

    #[test]
    fn unwritten_regs_read_zero() {
        let cs = ConfigSpace::default();
        assert_eq!(cs.read(0x33), 0);
    }
}
