//! DOE (Data Object Exchange) mailbox + DSLBIS latency reporting.
//!
//! CXL endpoints expose CDAT (Coherent Device Attribute Table) structures
//! through the PCIe DOE capability. The paper's reflector reads each
//! CXL-SSD's **DSLBIS** (Device Scoped Latency and Bandwidth Information
//! Structure) entry during enumeration to learn the device's internal
//! access latency, then adds the virtual-hierarchy path latency to form
//! the end-to-end value it writes back into the device's config space.

use crate::sim::time::Ps;

/// One DSLBIS entry (we model the read-latency entry; CDAT expresses
/// latency in picosecond units natively, matching our time base).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dslbis {
    /// DSMAS handle this entry scopes (we model one memory range).
    pub handle: u8,
    /// Read access latency from the device's CXL port to data (ps).
    pub read_latency_ps: Ps,
    /// Write latency (ps).
    pub write_latency_ps: Ps,
    /// Read bandwidth in MB/s (informational).
    pub read_bw_mbps: u64,
}

/// The DOE mailbox of one endpoint: answers CDAT read requests.
#[derive(Debug, Clone)]
pub struct DoeMailbox {
    entries: Vec<Dslbis>,
}

impl DoeMailbox {
    pub fn new(entries: Vec<Dslbis>) -> Self {
        DoeMailbox { entries }
    }

    /// CDAT "read entry" exchange. Returns `None` for an unknown handle
    /// (hosts must tolerate sparse handles).
    pub fn read_dslbis(&self, handle: u8) -> Option<Dslbis> {
        self.entries.iter().copied().find(|e| e.handle == handle)
    }

    /// All advertised entries (host-side table walk).
    pub fn entries(&self) -> &[Dslbis] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_known_and_unknown_handles() {
        let mb = DoeMailbox::new(vec![Dslbis {
            handle: 0,
            read_latency_ps: 250_000,
            write_latency_ps: 1_000_000,
            read_bw_mbps: 32_000,
        }]);
        assert_eq!(mb.read_dslbis(0).unwrap().read_latency_ps, 250_000);
        assert!(mb.read_dslbis(7).is_none());
        assert_eq!(mb.entries().len(), 1);
    }
}
