//! CXL.mem transaction vocabulary (CXL 3.0), including the paper's two
//! custom opcodes.
//!
//! Downward (master-to-subordinate, M2S): `Req` carries MemRd without
//! payload; `RwD` carries payload (MemWr). The paper defines **MemRdPC**
//! in RwD's custom-opcode space so every LLC-missing read piggybacks the
//! current program counter to the decider.
//!
//! Upward (subordinate-to-master, S2M): `DRS`/`NDR` are normal responses;
//! `BISnp` is CXL 3.0 back-invalidation. The paper defines **BISnpData**
//! in BISnp's custom space so the decider can push prefetched lines into
//! the host-side reflector buffer.

/// M2S (host -> device) message classes and opcodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum M2S {
    /// Request without data: plain memory read.
    ReqMemRd,
    /// Request with data: memory write (64B payload).
    RwDMemWr,
    /// Custom RwD opcode: memory read carrying the PC (paper's MemRdPC).
    RwDMemRdPC,
    /// Back-invalidation response (host acks a BISnp).
    BIRsp,
}

/// S2M (device -> host) message classes and opcodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum S2M {
    /// Data response (carries 64B line).
    DrsMemData,
    /// No-data response (completion for writes).
    NdrCmp,
    /// Back-invalidation snoop (no payload).
    BISnp,
    /// Custom BISnp opcode: snoop + pushed prefetch payload (BISnpData).
    BISnpData,
}

/// Header+payload size in bytes of one transaction as it crosses a link.
/// CXL.mem slot formats: 16B header slots; data adds a 64B line (and
/// MemRdPC an 8B PC immediate packed into a second slot).
pub fn m2s_bytes(op: M2S) -> usize {
    match op {
        M2S::ReqMemRd => 16,
        M2S::RwDMemWr => 16 + 64,
        M2S::RwDMemRdPC => 16 + 8,
        M2S::BIRsp => 16,
    }
}

/// Size of an S2M transaction on the wire.
pub fn s2m_bytes(op: S2M) -> usize {
    match op {
        S2M::DrsMemData => 16 + 64,
        S2M::NdrCmp => 16,
        S2M::BISnp => 16,
        S2M::BISnpData => 16 + 64,
    }
}

/// Message counters for traffic accounting (per device).
#[derive(Debug, Clone, Copy, Default)]
pub struct TrafficStats {
    pub m2s_req: u64,
    pub m2s_rdpc: u64,
    pub m2s_wr: u64,
    pub m2s_birsp: u64,
    pub s2m_drs: u64,
    pub s2m_ndr: u64,
    pub s2m_bisnp: u64,
    pub s2m_bisnpdata: u64,
    /// CXL.io sideband messages (reflector hit notifications).
    pub m2s_io: u64,
    pub bytes_down: u64,
    pub bytes_up: u64,
}

impl TrafficStats {
    pub fn record_m2s(&mut self, op: M2S) {
        self.bytes_down += m2s_bytes(op) as u64;
        match op {
            M2S::ReqMemRd => self.m2s_req += 1,
            M2S::RwDMemRdPC => self.m2s_rdpc += 1,
            M2S::RwDMemWr => self.m2s_wr += 1,
            M2S::BIRsp => self.m2s_birsp += 1,
        }
    }

    pub fn record_io(&mut self, bytes: usize) {
        self.m2s_io += 1;
        self.bytes_down += bytes as u64;
    }

    pub fn record_s2m(&mut self, op: S2M) {
        self.bytes_up += s2m_bytes(op) as u64;
        match op {
            S2M::DrsMemData => self.s2m_drs += 1,
            S2M::NdrCmp => self.s2m_ndr += 1,
            S2M::BISnp => self.s2m_bisnp += 1,
            S2M::BISnpData => self.s2m_bisnpdata += 1,
        }
    }

    /// Accumulate another record into this one (the multi-host engine
    /// merges per-shard endpoint traffic into the pool-wide totals at
    /// each epoch barrier).
    pub fn merge(&mut self, o: &TrafficStats) {
        self.m2s_req += o.m2s_req;
        self.m2s_rdpc += o.m2s_rdpc;
        self.m2s_wr += o.m2s_wr;
        self.m2s_birsp += o.m2s_birsp;
        self.s2m_drs += o.s2m_drs;
        self.s2m_ndr += o.s2m_ndr;
        self.s2m_bisnp += o.s2m_bisnp;
        self.s2m_bisnpdata += o.s2m_bisnpdata;
        self.m2s_io += o.m2s_io;
        self.bytes_down += o.bytes_down;
        self.bytes_up += o.bytes_up;
    }

    /// Counters accrued since `prev` (one epoch's worth of traffic; all
    /// counters are monotone, so plain subtraction is exact).
    pub fn delta_since(&self, prev: &TrafficStats) -> TrafficStats {
        TrafficStats {
            m2s_req: self.m2s_req - prev.m2s_req,
            m2s_rdpc: self.m2s_rdpc - prev.m2s_rdpc,
            m2s_wr: self.m2s_wr - prev.m2s_wr,
            m2s_birsp: self.m2s_birsp - prev.m2s_birsp,
            s2m_drs: self.s2m_drs - prev.s2m_drs,
            s2m_ndr: self.s2m_ndr - prev.s2m_ndr,
            s2m_bisnp: self.s2m_bisnp - prev.s2m_bisnp,
            s2m_bisnpdata: self.s2m_bisnpdata - prev.s2m_bisnpdata,
            m2s_io: self.m2s_io - prev.m2s_io,
            bytes_down: self.bytes_down - prev.bytes_down,
            bytes_up: self.bytes_up - prev.bytes_up,
        }
    }

    /// Total request-class messages (demand reads + writes) — the unit
    /// the epoch contention model charges queuing against.
    pub fn requests(&self) -> u64 {
        self.m2s_req + self.m2s_rdpc + self.m2s_wr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_sizes() {
        assert_eq!(m2s_bytes(M2S::ReqMemRd), 16);
        assert_eq!(m2s_bytes(M2S::RwDMemRdPC), 24); // header + PC
        assert_eq!(s2m_bytes(S2M::DrsMemData), 80); // header + line
        assert_eq!(s2m_bytes(S2M::BISnpData), 80); // snoop + pushed line
        assert_eq!(s2m_bytes(S2M::BISnp), 16); // plain snoop, no payload
    }

    #[test]
    fn traffic_accounting() {
        let mut t = TrafficStats::default();
        t.record_m2s(M2S::RwDMemRdPC);
        t.record_s2m(S2M::DrsMemData);
        t.record_s2m(S2M::BISnpData);
        assert_eq!(t.m2s_rdpc, 1);
        assert_eq!(t.s2m_drs, 1);
        assert_eq!(t.s2m_bisnpdata, 1);
        assert_eq!(t.bytes_down, 24);
        assert_eq!(t.bytes_up, 160);
    }
}
