//! Fabric latency/queuing model: message traversal over the enumerated
//! topology with per-link serialization and switch store-and-forward.
//!
//! This is what makes the CXL-SSD's *position* in the switch network
//! matter (paper § "Latency Variation with CXL Switch Topology"): each
//! switch level adds processing + serialization delay in both directions,
//! and links are serially-reusable resources (queuing under load).
//!
//! Hot-path layout: node ids are dense indices into the topology's node
//! array, so all per-node state — RC-to-node paths, hop/switch counts,
//! link next-free times, per-endpoint traffic counters — lives in flat
//! `Vec`s indexed by node id. Paths are computed once at construction;
//! a traversal walks the cached path slice without allocating (the seed
//! rebuilt the path `Vec` and consulted `BTreeMap`s on every message).

use super::flit::serialize_ps;
use super::topology::{NodeId, NodeKind, Topology};
use super::transaction::{m2s_bytes, s2m_bytes, M2S, S2M, TrafficStats};
use crate::config::CxlConfig;
use crate::sim::time::{ns, Ps};
use std::sync::Arc;

/// Direction of a traversal (affects which port queue is used).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dir {
    Down = 0,
    Up = 1,
}

/// Arbitration lane: demand traffic preempts prefetch-class traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Lane {
    /// Demand requests/responses (MemRd/MemRdPC/DRS).
    Demand,
    /// Prefetch-class traffic (BISnpData pushes, CXL.io notifications):
    /// yields to demand reservations so speculative data movement cannot
    /// head-of-line-block the application.
    Prefetch,
}

/// Read-only traversal plan: topology plus the dense per-node path/latency
/// tables. Every host fabric in a multi-host run shares one plan behind an
/// `Arc` — at fleet scale (256+ host contexts) rebuilding or duplicating the
/// path tables per host would dominate both construction time and memory,
/// while the tables themselves never change after enumeration.
#[derive(Debug, Clone)]
pub struct FabricPlan {
    pub topo: Topology,
    cfg: CxlConfig,
    /// RC-to-node path (inclusive both ends), indexed by node id —
    /// computed once so traversals never rebuild it.
    paths: Vec<Vec<NodeId>>,
    /// Links on the RC-to-node path, indexed by node id.
    hops: Vec<u64>,
    /// Switches on the RC-to-node path, indexed by node id.
    switches: Vec<u64>,
    /// Whether a node is a switch (store-and-forward on crossing into it).
    is_switch: Vec<bool>,
}

impl FabricPlan {
    pub fn new(topo: Topology, cfg: &CxlConfig) -> Self {
        let n = topo.nodes.len();
        let paths: Vec<Vec<NodeId>> = (0..n).map(|i| topo.path_from_root(i)).collect();
        let hops = paths.iter().map(|p| (p.len() - 1) as u64).collect();
        let switches = (0..n).map(|i| topo.switch_depth(i) as u64).collect();
        let is_switch = topo.nodes.iter().map(|nd| nd.kind == NodeKind::Switch).collect();
        FabricPlan { topo, cfg: cfg.clone(), paths, hops, switches, is_switch }
    }
}

/// The fabric: a shared read-only plan + this host's mutable link
/// availability and traffic accounting.
#[derive(Debug, Clone)]
pub struct Fabric {
    plan: Arc<FabricPlan>,
    /// Per (child-node, direction) demand-lane next-free time, dense by
    /// child node id. The link between a node and its parent is keyed by
    /// the child id.
    link_free: Vec<[Ps; 2]>,
    /// Per-node traffic counters (only endpoints are ever recorded).
    traffic: Vec<TrafficStats>,
}

impl Fabric {
    pub fn new(topo: Topology, cfg: &CxlConfig) -> Self {
        Self::from_plan(Arc::new(FabricPlan::new(topo, cfg)))
    }

    /// A fresh fabric (idle links, zero traffic) over an existing shared
    /// plan — the per-host constructor the fleet engine uses.
    pub fn from_plan(plan: Arc<FabricPlan>) -> Self {
        let n = plan.topo.nodes.len();
        Fabric { plan, link_free: vec![[0; 2]; n], traffic: vec![TrafficStats::default(); n] }
    }

    /// The shared plan (cheap `Arc` clone).
    pub fn plan(&self) -> Arc<FabricPlan> {
        self.plan.clone()
    }

    pub fn topo(&self) -> &Topology {
        &self.plan.topo
    }

    pub fn cfg(&self) -> &CxlConfig {
        &self.plan.cfg
    }

    /// Pure propagation latency (no queuing) of `bytes` from RC to
    /// `dev` (or back — symmetric): per-hop link latency + serialization,
    /// plus per-switch processing, plus RC processing. Inlined so the
    /// batched miss path (endpoint index already resolved by the batch
    /// route pass) folds this into two table loads and a fused
    /// multiply-add.
    #[inline]
    pub fn path_latency(&self, dev: NodeId, bytes: usize) -> Ps {
        let plan = &*self.plan;
        let ser = serialize_ps(&plan.cfg, bytes);
        ns(plan.cfg.rc_latency_ns)
            + plan.hops[dev] * (ns(plan.cfg.link_latency_ns) + ser)
            + plan.switches[dev] * ns(plan.cfg.switch_latency_ns)
    }

    /// Queued traversal at absolute time `now`: walks the path charging
    /// each link's next-free time. Returns arrival time at the far end.
    fn traverse(&mut self, dev: NodeId, now: Ps, bytes: usize, dir: Dir) -> Ps {
        self.traverse_lane(dev, now, bytes, dir, Lane::Demand)
    }

    fn traverse_lane(&mut self, dev: NodeId, now: Ps, bytes: usize, dir: Dir, lane: Lane) -> Ps {
        // Disjoint field borrow: `plan` pins only `self.plan`, leaving
        // `self.link_free` free for mutation below.
        let plan = &*self.plan;
        let ser = serialize_ps(&plan.cfg, bytes);
        let link_lat = ns(plan.cfg.link_latency_ns);
        let switch_lat = ns(plan.cfg.switch_latency_ns);
        let mut t = now + ns(plan.cfg.rc_latency_ns);
        // Walk link by link: link i connects path[i] and path[i+1], keyed
        // by the child (path[i+1]); Up iterates the same links deepest
        // child first. The path slice is borrowed from the precomputed
        // table — no per-traversal allocation.
        let path = &plan.paths[dev];
        let links = path.len() - 1;
        let d = dir as usize;
        for k in 0..links {
            let child = match dir {
                Dir::Down => path[k + 1],
                Dir::Up => path[links - k],
            };
            let hi = self.link_free[child][d];
            let start = match lane {
                // Demand ignores prefetch-lane traffic (priority) and
                // reserves the link while serializing.
                Lane::Demand => {
                    let s = t.max(hi);
                    self.link_free[child][d] = s + ser;
                    s
                }
                // Prefetch-class traffic yields to demand reservations
                // but does not reserve capacity itself: push traffic is
                // ~0.7 GB/s against a ~60 GB/s link, and pushes are
                // scheduled at out-of-order future deadlines — eager
                // reservation would head-of-line-block later pushes that
                // are due earlier (see EXPERIMENTS.md §Perf).
                Lane::Prefetch => t.max(hi),
            };
            let done = start + link_lat + ser;
            // Switch store-and-forward after crossing into a switch.
            t = if plan.is_switch[child] { done + switch_lat } else { done };
        }
        t
    }

    /// Host-side read round trip: M2S request down, device service time
    /// `service` at the endpoint, S2M DRS data response up.
    /// Returns total latency (arrival of data at RC minus `now`).
    pub fn read_roundtrip(
        &mut self,
        dev: NodeId,
        now: Ps,
        req: M2S,
        service: Ps,
    ) -> Ps {
        if let Some(t) = self.traffic.get_mut(dev) {
            t.record_m2s(req);
            t.record_s2m(S2M::DrsMemData);
        }
        let at_dev = self.traverse(dev, now, m2s_bytes(req), Dir::Down);
        let done_dev = at_dev + service;
        let at_host = self.traverse(dev, done_dev, s2m_bytes(S2M::DrsMemData), Dir::Up);
        at_host - now
    }

    /// Dirty-eviction writeback round trip: M2S `RwDMemWr` (header +
    /// 64 B payload) down, device commit `service`, S2M `NdrCmp` up.
    /// Returns total latency (completion at RC minus `now`); callers
    /// typically run it off the critical path but the link occupancy and
    /// per-endpoint traffic are real either way.
    pub fn write_roundtrip(&mut self, dev: NodeId, now: Ps, service: Ps) -> Ps {
        if let Some(t) = self.traffic.get_mut(dev) {
            t.record_m2s(M2S::RwDMemWr);
            t.record_s2m(S2M::NdrCmp);
        }
        let at_dev = self.traverse(dev, now, m2s_bytes(M2S::RwDMemWr), Dir::Down);
        let done_dev = at_dev + service;
        let at_host = self.traverse(dev, done_dev, s2m_bytes(S2M::NdrCmp), Dir::Up);
        at_host - now
    }

    /// Device-initiated back-invalidation round trip: S2M `BISnp` up
    /// (no payload), host invalidates, M2S `BIRsp` ack down. Coherence
    /// traffic rides the demand lane — a snoop cannot be deferred behind
    /// speculative pushes.
    pub fn bi_invalidate(&mut self, dev: NodeId, now: Ps) -> Ps {
        if let Some(t) = self.traffic.get_mut(dev) {
            t.record_s2m(S2M::BISnp);
            t.record_m2s(M2S::BIRsp);
        }
        let at_host = self.traverse(dev, now, s2m_bytes(S2M::BISnp), Dir::Up);
        let at_dev = self.traverse(dev, at_host, m2s_bytes(M2S::BIRsp), Dir::Down);
        at_dev - now
    }

    /// Upward push (decider -> reflector) via BISnpData: one-way S2M with
    /// payload, plus the host's BIRsp ack (not on the critical path).
    pub fn bisnp_push(&mut self, dev: NodeId, now: Ps) -> Ps {
        if let Some(t) = self.traffic.get_mut(dev) {
            t.record_s2m(S2M::BISnpData);
            t.record_m2s(M2S::BIRsp);
        }
        let at_host =
            self.traverse_lane(dev, now, s2m_bytes(S2M::BISnpData), Dir::Up, Lane::Prefetch);
        at_host - now
    }

    /// One-way host -> device notification (CXL.io hit notify, small).
    pub fn io_notify(&mut self, dev: NodeId, now: Ps) -> Ps {
        if let Some(t) = self.traffic.get_mut(dev) {
            t.record_io(16);
        }
        let at_dev = self.traverse_lane(dev, now, 16, Dir::Down, Lane::Prefetch);
        at_dev - now
    }

    /// Replay cost of one LRSM-style link retry on `dev`'s path: the
    /// receiver NAKs the corrupted flit and the sender replays it from
    /// the retry buffer, so the access pays one extra flit round trip on
    /// the deepest link plus the flit's reserialization — latency only,
    /// never a failure (CXL physical-layer CRC + retry semantics).
    pub fn crc_replay_ps(&self, _dev: NodeId) -> Ps {
        let cfg = &self.plan.cfg;
        2 * ns(cfg.link_latency_ns) + serialize_ps(cfg, cfg.flit_bytes)
    }

    /// Per-endpoint traffic counters (zero record for non-endpoints and
    /// out-of-range ids). The multi-host engine snapshots each shard
    /// fabric's endpoint rows at epoch boundaries and merges the deltas
    /// into pool-wide totals at the barrier (`TrafficStats::merge` /
    /// `delta_since`) — the fabric itself never sees cross-thread
    /// mutation.
    pub fn traffic_for(&self, dev: NodeId) -> TrafficStats {
        self.traffic.get(dev).copied().unwrap_or_default()
    }

    /// Cumulative M2S request count for one endpoint — the cheap
    /// occupancy column the observability time series samples per epoch
    /// (full [`TrafficStats`] snapshots stay reserved for the engine's
    /// barrier merges).
    pub fn requests_for(&self, dev: NodeId) -> u64 {
        self.traffic.get(dev).map(|t| t.requests()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CxlConfig;

    fn fabric(levels: usize) -> (Fabric, NodeId) {
        let topo = Topology::chain(levels);
        let ssd = topo.ssds()[0];
        (Fabric::new(topo, &CxlConfig::default()), ssd)
    }

    #[test]
    fn deeper_topology_is_slower() {
        let mut prev = 0;
        for levels in 0..5 {
            let (f, ssd) = fabric(levels);
            let lat = f.path_latency(ssd, 80);
            assert!(lat > prev, "level {levels}: {lat} > {prev}");
            prev = lat;
        }
    }

    #[test]
    fn per_level_increment_is_switch_plus_link() {
        let (f1, s1) = fabric(1);
        let (f2, s2) = fabric(2);
        let d = f2.path_latency(s2, 80) - f1.path_latency(s1, 80);
        let cfg = CxlConfig::default();
        let expect = ns(cfg.switch_latency_ns) + ns(cfg.link_latency_ns)
            + serialize_ps(&cfg, 80);
        assert_eq!(d, expect);
    }

    #[test]
    fn cached_path_tables_match_topology_walk() {
        // The dense per-node tables must agree with the (allocating)
        // topology walk they replaced.
        let topo = Topology::parse_custom("(x, s(z, p), s(s(d)))").unwrap();
        let f = Fabric::new(topo.clone(), &CxlConfig::default());
        for node in 0..topo.nodes.len() {
            assert_eq!(f.plan.paths[node], topo.path_from_root(node), "node {node}");
            assert_eq!(f.plan.hops[node] as usize, topo.path_from_root(node).len() - 1);
            assert_eq!(f.plan.switches[node] as usize, topo.switch_depth(node));
        }
        // Host fabrics built from a shared plan start idle and share tables.
        let g = Fabric::from_plan(f.plan());
        assert_eq!(g.plan.paths, f.plan.paths);
        assert_eq!(g.requests_for(0), 0);
    }

    #[test]
    fn roundtrip_includes_service_and_both_directions() {
        let (mut f, ssd) = fabric(1);
        let service = 1_000_000; // 1 us
        let rt = f.read_roundtrip(ssd, 0, M2S::ReqMemRd, service);
        let one_way = f.path_latency(ssd, 16);
        assert!(rt > service + one_way, "rt {rt}");
        // Traffic recorded.
        let t = f.traffic_for(ssd);
        assert_eq!(t.m2s_req, 1);
        assert_eq!(t.s2m_drs, 1);
    }

    #[test]
    fn link_contention_queues_messages() {
        let (mut f, ssd) = fabric(1);
        // Two requests at the same instant: the second serializes behind
        // the first on the shared link.
        let a = f.read_roundtrip(ssd, 0, M2S::ReqMemRd, 0);
        let b = f.read_roundtrip(ssd, 0, M2S::ReqMemRd, 0);
        assert!(b > a, "queued {b} > first {a}");
    }

    #[test]
    fn sibling_endpoints_queue_on_shared_upstream_link() {
        // Two SSDs behind the same switch: the RC->switch link is shared,
        // so simultaneous requests to *different* endpoints serialize.
        let topo = Topology::tree(1, 1, 2);
        let ssds = topo.ssds();
        assert_eq!(ssds.len(), 2);
        let mut f = Fabric::new(topo, &CxlConfig::default());
        let a = f.read_roundtrip(ssds[0], 0, M2S::ReqMemRd, 0);
        let b = f.read_roundtrip(ssds[1], 0, M2S::ReqMemRd, 0);
        assert!(b > a, "shared-link queuing: {b} > {a}");
        // Traffic is accounted per endpoint, not pooled.
        assert_eq!(f.traffic_for(ssds[0]).m2s_req, 1);
        assert_eq!(f.traffic_for(ssds[1]).m2s_req, 1);
        assert_eq!(f.traffic_for(ssds[0]).s2m_drs, 1);
    }

    #[test]
    fn io_notify_records_per_endpoint_traffic() {
        let topo = Topology::tree(1, 2, 2);
        let ssds = topo.ssds();
        let mut f = Fabric::new(topo, &CxlConfig::default());
        f.io_notify(ssds[1], 0);
        assert_eq!(f.traffic_for(ssds[1]).m2s_io, 1);
        assert_eq!(f.traffic_for(ssds[1]).bytes_down, 16);
        assert_eq!(f.traffic_for(ssds[0]).m2s_io, 0);
    }

    #[test]
    fn write_roundtrip_records_memwr_and_ndr() {
        let (mut f, ssd) = fabric(1);
        let service = 500_000;
        let wr = f.write_roundtrip(ssd, 0, service);
        // Both directions + service: strictly more than one-way + service.
        assert!(wr > service + f.path_latency(ssd, 16), "wr {wr}");
        let t = f.traffic_for(ssd);
        assert_eq!(t.m2s_wr, 1);
        assert_eq!(t.s2m_ndr, 1);
        // Payload accounted downward: header + 64B line.
        assert_eq!(t.bytes_down, 80);
        assert_eq!(t.bytes_up, 16);
    }

    #[test]
    fn bi_invalidate_records_bisnp_and_birsp() {
        let (mut f, ssd) = fabric(2);
        let rt = f.bi_invalidate(ssd, 0);
        assert!(rt > f.path_latency(ssd, 16), "round trip {rt} exceeds one-way");
        let t = f.traffic_for(ssd);
        assert_eq!(t.s2m_bisnp, 1);
        assert_eq!(t.m2s_birsp, 1);
        assert_eq!(t.bytes_up, 16);
        assert_eq!(t.bytes_down, 16);
    }

    #[test]
    fn deeper_endpoint_pays_more_for_bi_invalidate() {
        let (mut f1, s1) = fabric(1);
        let (mut f3, s3) = fabric(3);
        assert!(f3.bi_invalidate(s3, 0) > f1.bi_invalidate(s1, 0));
    }

    #[test]
    fn crc_replay_costs_a_flit_round_trip() {
        let (f, ssd) = fabric(2);
        let cfg = CxlConfig::default();
        let replay = f.crc_replay_ps(ssd);
        assert_eq!(replay, 2 * ns(cfg.link_latency_ns) + serialize_ps(&cfg, cfg.flit_bytes));
        // A retry is strictly cheaper than the full path it rides on.
        assert!(replay < f.path_latency(ssd, 80), "replay {replay}");
    }

    #[test]
    fn bisnp_push_is_one_way() {
        let (mut f, ssd) = fabric(2);
        let push = f.bisnp_push(ssd, 0);
        let rt = {
            let (mut f2, ssd2) = fabric(2);
            f2.read_roundtrip(ssd2, 0, M2S::ReqMemRd, 0)
        };
        assert!(push < rt, "one-way {push} < roundtrip {rt}");
        assert_eq!(f.traffic_for(ssd).s2m_bisnpdata, 1);
    }
}
