//! PCIe enumeration over the CXL fabric.
//!
//! The reflector identifies each CXL-SSD's switch level during standard
//! PCIe bus enumeration: switches behave as PCIe bridges, each consuming
//! a bus number, so depth-first traversal with secondary/subordinate bus
//! assignment reveals how many switches sit between the host and each
//! endpoint (paper § "CXL switch hierarchy discovery"). This module
//! reproduces that bus-numbering walk.

use super::topology::{NodeId, NodeKind, Topology};
use std::collections::BTreeMap;

/// Enumeration record for one fabric node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EnumInfo {
    /// Bus number the device answers on.
    pub bus: u8,
    /// Secondary bus (bridges only): first bus behind the bridge.
    pub secondary: u8,
    /// Subordinate bus (bridges only): highest bus behind the bridge.
    pub subordinate: u8,
    /// Switch count between RC and this node, derived from the walk.
    pub switch_depth: u8,
}

/// Result of enumerating a topology.
#[derive(Debug, Clone)]
pub struct Enumeration {
    pub info: BTreeMap<NodeId, EnumInfo>,
}

impl Enumeration {
    /// Depth-first enumeration assigning bus numbers exactly like a PCIe
    /// root complex: each bridge's secondary bus is the next free number;
    /// its subordinate bus is fixed up after its subtree is walked.
    pub fn discover(topo: &Topology) -> Self {
        let mut info = BTreeMap::new();
        let mut next_bus: u8 = 0;
        fn walk(
            topo: &Topology,
            node: NodeId,
            bus: u8,
            depth: u8,
            next_bus: &mut u8,
            info: &mut BTreeMap<NodeId, EnumInfo>,
        ) -> u8 {
            let is_bridge = matches!(
                topo.nodes[node].kind,
                NodeKind::RootComplex | NodeKind::Switch
            );
            let mut rec = EnumInfo { bus, secondary: bus, subordinate: bus, switch_depth: depth };
            if is_bridge && !topo.nodes[node].children.is_empty() {
                *next_bus = next_bus.wrapping_add(1);
                let child_bus = *next_bus;
                rec.secondary = child_bus;
                let child_depth =
                    depth + u8::from(topo.nodes[node].kind == NodeKind::Switch);
                let mut max_bus = child_bus;
                for &c in &topo.nodes[node].children {
                    // A leaf sibling enumerated after a bridge sibling
                    // reports the shared secondary bus, which is lower
                    // than the bridge subtree's range — subordinate must
                    // track the maximum across all children, not the last.
                    max_bus = max_bus.max(walk(topo, c, child_bus, child_depth, next_bus, info));
                }
                rec.subordinate = max_bus;
            }
            info.insert(node, rec);
            info.get(&node).unwrap().subordinate
        }
        walk(topo, topo.root, 0, 0, &mut next_bus, &mut info);
        // Children at the same level share a bus but each *bridge* child
        // consumes further numbers; subordinate already tracks the max.
        Enumeration { info }
    }

    /// Switch depth of a device, as the host would compute it from the
    /// number of bridges crossed.
    pub fn switch_depth(&self, node: NodeId) -> usize {
        self.info[&node].switch_depth as usize
    }

    /// Validate against the ground-truth topology (used by tests and the
    /// `expand enumerate` CLI's self-check).
    pub fn verify(&self, topo: &Topology) -> bool {
        topo.ssds()
            .iter()
            .all(|&s| self.switch_depth(s) == topo.switch_depth(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_enumeration_matches_depth() {
        for levels in 0..5 {
            let t = Topology::chain(levels);
            let e = Enumeration::discover(&t);
            assert!(e.verify(&t), "levels={levels}");
            let ssd = t.ssds()[0];
            assert_eq!(e.switch_depth(ssd), levels);
        }
    }

    #[test]
    fn tree_enumeration_depths_and_buses() {
        let t = Topology::tree(2, 2, 4);
        let e = Enumeration::discover(&t);
        assert!(e.verify(&t));
        // All SSDs behind two switch tiers.
        for s in t.ssds() {
            assert_eq!(e.switch_depth(s), 2);
        }
        // Bus numbers are unique per bridge subtree entry point.
        let root = e.info[&t.root];
        assert_eq!(root.bus, 0);
        assert!(root.subordinate >= root.secondary);
    }

    #[test]
    fn subordinate_covers_bridge_subtrees_before_leaf_siblings() {
        // A switch whose children are [bridge, leaf] in that order: the
        // leaf answers on the shared secondary bus, so the parent's
        // subordinate must still cover the bridge subtree's higher buses.
        let mut t = Topology::new();
        let sw = t.add(NodeKind::Switch, t.root);
        let deep = t.add(NodeKind::Switch, sw);
        t.add(NodeKind::CxlSsd, deep);
        t.add(NodeKind::CxlSsd, sw); // leaf sibling AFTER the bridge
        let e = Enumeration::discover(&t);
        assert!(e.verify(&t));
        let sw_rec = e.info[&sw];
        let deep_rec = e.info[&deep];
        assert!(
            deep_rec.subordinate <= sw_rec.subordinate,
            "bridge subtree {}..{} escapes parent range {}..{}",
            deep_rec.secondary,
            deep_rec.subordinate,
            sw_rec.secondary,
            sw_rec.subordinate
        );
    }

    #[test]
    fn bridge_ranges_nest() {
        let t = Topology::tree(2, 2, 2);
        let e = Enumeration::discover(&t);
        for node in &t.nodes {
            if node.kind == NodeKind::Switch {
                let rec = e.info[&node.id];
                for &c in &node.children {
                    let crec = e.info[&c];
                    assert!(
                        crec.bus >= rec.secondary && crec.bus <= rec.subordinate,
                        "child bus {} outside bridge range {}..={}",
                        crec.bus,
                        rec.secondary,
                        rec.subordinate
                    );
                }
            }
        }
    }
}
