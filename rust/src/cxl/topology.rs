//! CXL fabric topology: root complex, multi-tiered switches (CXL 3.0/3.1),
//! and endpoint CXL-SSDs, organized into virtual hierarchies.
//!
//! A switch exposes one upstream port (USP) toward the host and several
//! downstream ports (DSPs) toward deeper switches or endpoints. The
//! fabric manager binds ports into a *virtual hierarchy* (VH) — the
//! dedicated data path a host uses to reach its endpoints. The paper's
//! timeliness mechanism depends on knowing, per endpoint, how many switch
//! traversals its VH contains.

use crate::config::MediaKind;

/// Index into [`Topology::nodes`].
pub type NodeId = usize;

/// What a fabric node is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// Host root complex (one per topology here).
    RootComplex,
    /// CXL switch (PCIe bridge semantics for enumeration).
    Switch,
    /// CXL-SSD endpoint expander.
    CxlSsd,
}

/// One node in the fabric graph.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub kind: NodeKind,
    pub parent: Option<NodeId>,
    pub children: Vec<NodeId>,
    /// Endpoint media override (custom topologies only; `None` means the
    /// pool uses the configured default media).
    pub media: Option<MediaKind>,
}

/// The fabric graph (a tree rooted at the RC — one VH per host).
#[derive(Debug, Clone)]
pub struct Topology {
    pub nodes: Vec<Node>,
    pub root: NodeId,
}

impl Topology {
    /// New topology containing only a root complex.
    pub fn new() -> Self {
        Topology {
            nodes: vec![Node {
                id: 0,
                kind: NodeKind::RootComplex,
                parent: None,
                children: Vec::new(),
                media: None,
            }],
            root: 0,
        }
    }

    /// Add a node under `parent`.
    pub fn add(&mut self, kind: NodeKind, parent: NodeId) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            kind,
            parent: Some(parent),
            children: Vec::new(),
            media: None,
        });
        self.nodes[parent].children.push(id);
        id
    }

    /// Add a CXL-SSD endpoint with an optional media override.
    pub fn add_ssd(&mut self, parent: NodeId, media: Option<MediaKind>) -> NodeId {
        let id = self.add(NodeKind::CxlSsd, parent);
        self.nodes[id].media = media;
        id
    }

    /// Linear chain: RC -> `levels` switches -> one CXL-SSD. `levels == 0`
    /// attaches the SSD directly to the RC (the paper's no-switch
    /// baseline in Fig 2c).
    pub fn chain(levels: usize) -> Self {
        let mut t = Topology::new();
        let mut parent = t.root;
        for _ in 0..levels {
            parent = t.add(NodeKind::Switch, parent);
        }
        t.add(NodeKind::CxlSsd, parent);
        t
    }

    /// Balanced tree: `levels` tiers of switches with `fanout` DSPs each;
    /// SSD endpoints hang off the leaf tier (`ssds` of them, round-robin).
    pub fn tree(levels: usize, fanout: usize, ssds: usize) -> Self {
        let mut t = Topology::new();
        let mut frontier = vec![t.root];
        for _ in 0..levels {
            let mut next = Vec::new();
            for &p in &frontier {
                for _ in 0..fanout {
                    next.push(t.add(NodeKind::Switch, p));
                }
            }
            frontier = next;
        }
        for i in 0..ssds.max(1) {
            let p = frontier[i % frontier.len()];
            t.add(NodeKind::CxlSsd, p);
        }
        t
    }

    /// Parse a custom tree description: a parenthesized child list under
    /// the root complex, where `s(...)` is a switch and `x`/`z`/`p`/`d`
    /// are CXL-SSD endpoints (`x` = config-default media; the letters
    /// force Z-NAND / PMEM / DRAM). Example: `(x,s(x,x),s(s(z,p)))`
    /// hangs one endpoint directly off the RC, two behind one switch, and
    /// a Z-NAND + PMEM pair behind two switch tiers.
    pub fn parse_custom(spec: &str) -> anyhow::Result<Topology> {
        fn parse_children(
            t: &mut Topology,
            parent: NodeId,
            chars: &[char],
            pos: &mut usize,
        ) -> anyhow::Result<()> {
            anyhow::ensure!(
                chars.get(*pos) == Some(&'('),
                "topology spec: expected '(' at position {}",
                *pos
            );
            *pos += 1;
            loop {
                match chars.get(*pos) {
                    Some(&'s') => {
                        *pos += 1;
                        let sw = t.add(NodeKind::Switch, parent);
                        parse_children(t, sw, chars, pos)?;
                    }
                    Some(&c) if matches!(c, 'x' | 'z' | 'p' | 'd') => {
                        *pos += 1;
                        let media = match c {
                            'z' => Some(MediaKind::ZNand),
                            'p' => Some(MediaKind::Pmem),
                            'd' => Some(MediaKind::Dram),
                            _ => None,
                        };
                        t.add_ssd(parent, media);
                    }
                    other => anyhow::bail!(
                        "topology spec: expected 's' or endpoint (x|z|p|d) at position {}, \
                         got {other:?}",
                        *pos
                    ),
                }
                match chars.get(*pos) {
                    Some(&',') => *pos += 1,
                    Some(&')') => {
                        *pos += 1;
                        return Ok(());
                    }
                    other => anyhow::bail!(
                        "topology spec: expected ',' or ')' at position {}, got {other:?}",
                        *pos
                    ),
                }
            }
        }

        let chars: Vec<char> = spec.chars().filter(|c| !c.is_whitespace()).collect();
        let mut t = Topology::new();
        let root = t.root;
        let mut pos = 0usize;
        parse_children(&mut t, root, &chars, &mut pos)?;
        anyhow::ensure!(
            pos == chars.len(),
            "topology spec: trailing characters after position {pos}"
        );
        anyhow::ensure!(!t.ssds().is_empty(), "topology spec has no CXL-SSD endpoints");
        Ok(t)
    }

    /// All endpoint SSDs.
    pub fn ssds(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.kind == NodeKind::CxlSsd)
            .map(|n| n.id)
            .collect()
    }

    /// Path from the RC to `node` (inclusive both ends).
    pub fn path_from_root(&self, node: NodeId) -> Vec<NodeId> {
        let mut path = vec![node];
        let mut cur = node;
        while let Some(p) = self.nodes[cur].parent {
            path.push(p);
            cur = p;
        }
        path.reverse();
        path
    }

    /// Number of switches between the RC and `node`.
    pub fn switch_depth(&self, node: NodeId) -> usize {
        self.path_from_root(node)
            .iter()
            .filter(|&&id| self.nodes[id].kind == NodeKind::Switch)
            .count()
    }
}

impl Default for Topology {
    fn default() -> Self {
        Topology::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_depths() {
        for levels in 0..5 {
            let t = Topology::chain(levels);
            let ssds = t.ssds();
            assert_eq!(ssds.len(), 1);
            assert_eq!(t.switch_depth(ssds[0]), levels);
            // path = RC + switches + SSD
            assert_eq!(t.path_from_root(ssds[0]).len(), levels + 2);
        }
    }

    #[test]
    fn tree_shape() {
        let t = Topology::tree(2, 2, 4);
        // 1 RC + 2 + 4 switches + 4 SSDs
        assert_eq!(t.nodes.len(), 11);
        let ssds = t.ssds();
        assert_eq!(ssds.len(), 4);
        for s in ssds {
            assert_eq!(t.switch_depth(s), 2);
        }
    }

    #[test]
    fn path_starts_at_root_ends_at_node() {
        let t = Topology::chain(3);
        let ssd = t.ssds()[0];
        let p = t.path_from_root(ssd);
        assert_eq!(p[0], t.root);
        assert_eq!(*p.last().unwrap(), ssd);
    }

    #[test]
    fn custom_spec_builds_mixed_depths_and_media() {
        let t = Topology::parse_custom("(x, s(z, p), s(s(d)))").unwrap();
        let ssds = t.ssds();
        assert_eq!(ssds.len(), 4);
        let depths: Vec<usize> = ssds.iter().map(|&s| t.switch_depth(s)).collect();
        assert_eq!(depths, vec![0, 1, 1, 2]);
        let media: Vec<Option<MediaKind>> = ssds.iter().map(|&s| t.nodes[s].media).collect();
        assert_eq!(
            media,
            vec![
                None,
                Some(MediaKind::ZNand),
                Some(MediaKind::Pmem),
                Some(MediaKind::Dram)
            ]
        );
    }

    #[test]
    fn custom_spec_rejects_garbage() {
        assert!(Topology::parse_custom("").is_err());
        assert!(Topology::parse_custom("(s(x)").is_err(), "unterminated");
        assert!(Topology::parse_custom("(x)y").is_err(), "trailing");
        assert!(Topology::parse_custom("(q)").is_err(), "unknown endpoint");
        assert!(Topology::parse_custom("(s())").is_err(), "empty switch");
        assert!(Topology::parse_custom("(s(s(s())))").is_err(), "no endpoints");
    }
}
