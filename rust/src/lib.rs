//! # expand-cxl — ExPAND reproduction
//!
//! Full-system reproduction of *"CXL Topology-Aware and Expander-Driven
//! Prefetching: Unlocking SSD Performance"* (CS.AR 2025): a Rust
//! coordinator simulating the host (interval O3 cores + cache hierarchy),
//! the CXL fabric (multi-tier switches, enumeration, DOE/DSLBIS,
//! CXL.mem transactions with back-invalidation) and the CXL-SSD
//! (internal DRAM cache + Z-NAND/PMEM/DRAM backends), with the paper's
//! ML address predictors AOT-compiled from JAX/Pallas to HLO and executed
//! through the PJRT CPU client on the decider's hot path.
//!
//! Layering (see DESIGN.md):
//! * L3 (this crate) — coordination + every simulated substrate;
//! * L2 (`python/compile/model.py`) — predictor compute graphs, lowered
//!   once by `make artifacts`;
//! * L1 (`python/compile/kernels/mm_attention.py`) — fused
//!   multi-modality attention Pallas kernel inside the L2 graph.

pub mod coherence;
pub mod config;
pub mod cxl;
pub mod expand;
pub mod fault;
pub mod figures;
pub mod mem;
pub mod metrics;
pub mod obs;
pub mod prefetch;
pub mod runtime;
pub mod sim;
pub mod ssd;
pub mod trace;
pub mod util;
pub mod workloads;
