//! Minimal criterion-style bench harness (criterion is not in the
//! offline crate set): warmup + timed iterations, mean/min/stddev
//! reporting, and substring filtering via `cargo bench -- <filter>`.

use std::time::Instant;

pub struct Bench {
    filter: Option<String>,
    pub results: Vec<(String, f64)>,
}

impl Bench {
    pub fn from_args() -> Self {
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        Bench { filter, results: Vec::new() }
    }

    /// Run `f` repeatedly; prints mean/min/std. `iters` counts timed
    /// runs (after one warmup).
    pub fn bench<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) {
        if let Some(flt) = &self.filter {
            if !name.contains(flt.as_str()) {
                return;
            }
        }
        f(); // warmup
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / samples.len() as f64;
        println!(
            "bench {:<38} mean {:>10.3} ms   min {:>10.3} ms   sd {:>8.3} ms   ({} iters)",
            name,
            mean * 1e3,
            min * 1e3,
            var.sqrt() * 1e3,
            iters
        );
        self.results.push((name.to_string(), mean));
    }

    /// Report a throughput-style metric computed by the caller.
    pub fn report(&self, name: &str, value: f64, unit: &str) {
        if let Some(flt) = &self.filter {
            if !name.contains(flt.as_str()) {
                return;
            }
        }
        println!("metric {:<37} {:>14.1} {unit}", name, value);
    }
}
