//! Minimal criterion-style bench harness (criterion is not in the
//! offline crate set): warmup + timed iterations, mean/min/stddev
//! reporting, substring filtering via `cargo bench -- <filter>`, and an
//! end-to-end throughput mode whose results serialize to a tracked JSON
//! baseline (`BENCH_PR3.json`) with a regression check for CI.

use std::time::Instant;

pub struct Bench {
    filter: Option<String>,
    pub results: Vec<(String, f64)>,
}

impl Bench {
    pub fn with_filter(filter: Option<String>) -> Self {
        Bench { filter, results: Vec::new() }
    }

    /// Does `name` pass the CLI substring filter?
    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            Some(flt) => name.contains(flt.as_str()),
            None => true,
        }
    }

    /// Run `f` repeatedly; prints mean/min/std. `iters` counts timed
    /// runs (after one warmup).
    pub fn bench<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) {
        if !self.enabled(name) {
            return;
        }
        f(); // warmup
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / samples.len() as f64;
        println!(
            "bench {:<38} mean {:>10.3} ms   min {:>10.3} ms   sd {:>8.3} ms   ({} iters)",
            name,
            mean * 1e3,
            min * 1e3,
            var.sqrt() * 1e3,
            iters
        );
        self.results.push((name.to_string(), mean));
    }

    /// Report a throughput-style metric computed by the caller.
    pub fn report(&self, name: &str, value: f64, unit: &str) {
        if !self.enabled(name) {
            return;
        }
        println!("metric {:<37} {:>14.1} {unit}", name, value);
    }
}

/// One end-to-end simulator-throughput measurement (accesses/sec over
/// full `Runner` construction + trace replay).
#[derive(Debug, Clone)]
pub struct Throughput {
    pub name: String,
    pub accesses: u64,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    /// accesses / mean_s — the tracked headline number.
    pub mean_accesses_per_sec: f64,
    /// accesses / min_s — best observed iteration.
    pub best_accesses_per_sec: f64,
}

/// Measure `f` (one full simulation of `accesses` accesses) `iters`
/// times after one warmup.
pub fn measure_throughput<F: FnMut()>(
    name: &str,
    accesses: u64,
    iters: usize,
    mut f: F,
) -> Throughput {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
    let min_s = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let t = Throughput {
        name: name.to_string(),
        accesses,
        iters,
        mean_s,
        min_s,
        mean_accesses_per_sec: accesses as f64 / mean_s.max(1e-12),
        best_accesses_per_sec: accesses as f64 / min_s.max(1e-12),
    };
    println!(
        "throughput {:<33} mean {:>12.0} acc/s   best {:>12.0} acc/s   ({} x {} accesses)",
        t.name, t.mean_accesses_per_sec, t.best_accesses_per_sec, iters, accesses
    );
    t
}

/// Describe the measuring machine — emitted into every bench JSON so
/// tracked baselines carry their provenance automatically (the PR 3
/// baseline had to hand-record this and lost it on regeneration).
pub fn machine_description() -> String {
    format!(
        "{}-{}, {} cores, {} build",
        std::env::consts::OS,
        std::env::consts::ARCH,
        expand_cxl::util::default_parallelism(),
        if cfg!(debug_assertions) { "debug" } else { "release" },
    )
}

/// Serialize throughput results to the tracked JSON shape. Scenario
/// order is preserved; numbers round-trip through the in-repo JSON
/// parser. `prior` is the previous contents of the tracked file (or the
/// committed baseline): every top-level field the harness does not own
/// — `note`, pre-PR reference numbers, operator remarks — and every
/// unrecognized per-scenario field (matched by scenario name) is
/// carried over instead of being dropped on rewrite.
pub fn bench_json(suite: &str, results: &[Throughput], prior: Option<&str>) -> String {
    use expand_cxl::util::json::{self, Json};
    use std::collections::BTreeMap;

    let prior = prior.and_then(|t| json::parse(t).ok());
    const OWNED: &[&str] = &["schema", "suite", "machine", "scenarios"];
    const SCEN_OWNED: &[&str] = &[
        "name",
        "accesses",
        "iters",
        "mean_s",
        "min_s",
        "mean_accesses_per_sec",
        "best_accesses_per_sec",
    ];

    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    if let Some(Json::Obj(m)) = &prior {
        for (k, v) in m {
            if !OWNED.contains(&k.as_str()) {
                root.insert(k.clone(), v.clone());
            }
        }
    }
    root.insert("schema".into(), Json::Str("expand-cxl-bench/v1".into()));
    root.insert("suite".into(), Json::Str(suite.into()));
    root.insert("machine".into(), Json::Str(machine_description()));

    let empty: Vec<Json> = Vec::new();
    let prior_scenarios: &[Json] = prior
        .as_ref()
        .and_then(|p| p.get("scenarios"))
        .and_then(|s| s.as_arr())
        .unwrap_or(&empty);
    let round1 = |x: f64| (x * 10.0).round() / 10.0;
    let round6 = |x: f64| (x * 1e6).round() / 1e6;
    let scenarios: Vec<Json> = results
        .iter()
        .map(|t| {
            let mut m: BTreeMap<String, Json> = BTreeMap::new();
            let prior_row = prior_scenarios
                .iter()
                .find(|s| s.get("name").and_then(|n| n.as_str()) == Some(t.name.as_str()));
            if let Some(Json::Obj(pm)) = prior_row {
                for (k, v) in pm {
                    if !SCEN_OWNED.contains(&k.as_str()) {
                        m.insert(k.clone(), v.clone());
                    }
                }
            }
            m.insert("name".into(), Json::Str(t.name.clone()));
            m.insert("accesses".into(), Json::Num(t.accesses as f64));
            m.insert("iters".into(), Json::Num(t.iters as f64));
            m.insert("mean_s".into(), Json::Num(round6(t.mean_s)));
            m.insert("min_s".into(), Json::Num(round6(t.min_s)));
            m.insert(
                "mean_accesses_per_sec".into(),
                Json::Num(round1(t.mean_accesses_per_sec)),
            );
            m.insert(
                "best_accesses_per_sec".into(),
                Json::Num(round1(t.best_accesses_per_sec)),
            );
            Json::Obj(m)
        })
        .collect();
    root.insert("scenarios".into(), Json::Arr(scenarios));
    json::render(&Json::Obj(root))
}

/// Compare fresh results against a committed baseline JSON: every
/// scenario present in both must retain at least `1 - max_regress` of
/// the baseline's `mean_accesses_per_sec`. Returns the list of
/// regression messages (empty = pass).
pub fn check_against_baseline(
    baseline_text: &str,
    results: &[Throughput],
    max_regress: f64,
) -> Result<Vec<String>, String> {
    let doc = expand_cxl::util::json::parse(baseline_text)
        .map_err(|e| format!("baseline parse error: {e}"))?;
    let scenarios = doc
        .get("scenarios")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| "baseline has no scenarios array".to_string())?;
    let mut failures = Vec::new();
    // Every baseline scenario must have been re-measured — a renamed or
    // deleted scenario must not make the gate pass vacuously.
    for s in scenarios {
        let Some(name) = s.get("name").and_then(|n| n.as_str()) else { continue };
        if !results.iter().any(|t| t.name == name) {
            failures.push(format!("{name}: in baseline but not measured by this run"));
        }
    }
    for t in results {
        let Some(base) = scenarios.iter().find(|s| {
            s.get("name").and_then(|n| n.as_str()) == Some(t.name.as_str())
        }) else {
            continue; // new scenario: nothing to regress against
        };
        let Some(base_aps) = base.get("mean_accesses_per_sec").and_then(|v| v.as_f64()) else {
            continue;
        };
        let floor = base_aps * (1.0 - max_regress);
        if t.mean_accesses_per_sec < floor {
            failures.push(format!(
                "{}: {:.0} acc/s < floor {:.0} acc/s (baseline {:.0}, max regression {:.0}%)",
                t.name,
                t.mean_accesses_per_sec,
                floor,
                base_aps,
                max_regress * 100.0
            ));
        }
    }
    Ok(failures)
}
