//! Minimal criterion-style bench harness (criterion is not in the
//! offline crate set): warmup + timed iterations, mean/min/stddev
//! reporting, substring filtering via `cargo bench -- <filter>`, and an
//! end-to-end throughput mode whose results serialize to a tracked JSON
//! baseline (`BENCH_PR3.json`) with a regression check for CI.

use std::time::Instant;

pub struct Bench {
    filter: Option<String>,
    pub results: Vec<(String, f64)>,
}

impl Bench {
    pub fn with_filter(filter: Option<String>) -> Self {
        Bench { filter, results: Vec::new() }
    }

    /// Does `name` pass the CLI substring filter?
    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            Some(flt) => name.contains(flt.as_str()),
            None => true,
        }
    }

    /// Run `f` repeatedly; prints mean/min/std. `iters` counts timed
    /// runs (after one warmup).
    pub fn bench<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) {
        if !self.enabled(name) {
            return;
        }
        f(); // warmup
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / samples.len() as f64;
        println!(
            "bench {:<38} mean {:>10.3} ms   min {:>10.3} ms   sd {:>8.3} ms   ({} iters)",
            name,
            mean * 1e3,
            min * 1e3,
            var.sqrt() * 1e3,
            iters
        );
        self.results.push((name.to_string(), mean));
    }

    /// Report a throughput-style metric computed by the caller.
    pub fn report(&self, name: &str, value: f64, unit: &str) {
        if !self.enabled(name) {
            return;
        }
        println!("metric {:<37} {:>14.1} {unit}", name, value);
    }
}

/// One end-to-end simulator-throughput measurement (accesses/sec over
/// full `Runner` construction + trace replay).
#[derive(Debug, Clone)]
pub struct Throughput {
    pub name: String,
    pub accesses: u64,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    /// accesses / mean_s — the tracked headline number.
    pub mean_accesses_per_sec: f64,
    /// accesses / min_s — best observed iteration.
    pub best_accesses_per_sec: f64,
}

/// Measure `f` (one full simulation of `accesses` accesses) `iters`
/// times after one warmup.
pub fn measure_throughput<F: FnMut()>(
    name: &str,
    accesses: u64,
    iters: usize,
    mut f: F,
) -> Throughput {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
    let min_s = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let t = Throughput {
        name: name.to_string(),
        accesses,
        iters,
        mean_s,
        min_s,
        mean_accesses_per_sec: accesses as f64 / mean_s.max(1e-12),
        best_accesses_per_sec: accesses as f64 / min_s.max(1e-12),
    };
    println!(
        "throughput {:<33} mean {:>12.0} acc/s   best {:>12.0} acc/s   ({} x {} accesses)",
        t.name, t.mean_accesses_per_sec, t.best_accesses_per_sec, iters, accesses
    );
    t
}

/// Serialize throughput results to the tracked JSON shape. Scenario
/// order is preserved; numbers are written with enough precision to
/// round-trip through the in-repo JSON parser.
pub fn bench_json(suite: &str, results: &[Throughput]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"expand-cxl-bench/v1\",\n");
    out.push_str(&format!("  \"suite\": {suite:?},\n"));
    out.push_str("  \"scenarios\": [\n");
    for (i, t) in results.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!("      \"name\": {:?},\n", t.name));
        out.push_str(&format!("      \"accesses\": {},\n", t.accesses));
        out.push_str(&format!("      \"iters\": {},\n", t.iters));
        out.push_str(&format!("      \"mean_s\": {:.6},\n", t.mean_s));
        out.push_str(&format!("      \"min_s\": {:.6},\n", t.min_s));
        out.push_str(&format!(
            "      \"mean_accesses_per_sec\": {:.1},\n",
            t.mean_accesses_per_sec
        ));
        out.push_str(&format!(
            "      \"best_accesses_per_sec\": {:.1}\n",
            t.best_accesses_per_sec
        ));
        out.push_str(if i + 1 == results.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// Compare fresh results against a committed baseline JSON: every
/// scenario present in both must retain at least `1 - max_regress` of
/// the baseline's `mean_accesses_per_sec`. Returns the list of
/// regression messages (empty = pass).
pub fn check_against_baseline(
    baseline_text: &str,
    results: &[Throughput],
    max_regress: f64,
) -> Result<Vec<String>, String> {
    let doc = expand_cxl::util::json::parse(baseline_text)
        .map_err(|e| format!("baseline parse error: {e}"))?;
    let scenarios = doc
        .get("scenarios")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| "baseline has no scenarios array".to_string())?;
    let mut failures = Vec::new();
    // Every baseline scenario must have been re-measured — a renamed or
    // deleted scenario must not make the gate pass vacuously.
    for s in scenarios {
        let Some(name) = s.get("name").and_then(|n| n.as_str()) else { continue };
        if !results.iter().any(|t| t.name == name) {
            failures.push(format!("{name}: in baseline but not measured by this run"));
        }
    }
    for t in results {
        let Some(base) = scenarios.iter().find(|s| {
            s.get("name").and_then(|n| n.as_str()) == Some(t.name.as_str())
        }) else {
            continue; // new scenario: nothing to regress against
        };
        let Some(base_aps) = base.get("mean_accesses_per_sec").and_then(|v| v.as_f64()) else {
            continue;
        };
        let floor = base_aps * (1.0 - max_regress);
        if t.mean_accesses_per_sec < floor {
            failures.push(format!(
                "{}: {:.0} acc/s < floor {:.0} acc/s (baseline {:.0}, max regression {:.0}%)",
                t.name,
                t.mean_accesses_per_sec,
                floor,
                base_aps,
                max_regress * 100.0
            ));
        }
    }
    Ok(failures)
}
