//! Paper benches: one end-to-end bench per table/figure family, the
//! micro-benches used by the §Perf optimization log, and the
//! `runner_throughput` group — four end-to-end simulator-throughput
//! scenarios whose results serialize to `BENCH_PR3.json` at the repo
//! root (the tracked bench baseline; CI fails on >20% regression).
//!
//! Run: `cargo bench` (optionally `cargo bench -- <filter>`). Flags
//! after the filter:
//!   --json-out PATH      write throughput results as JSON (default
//!                        ../BENCH_PR3.json when the group runs)
//!   --check PATH         compare against a baseline JSON and exit
//!                        non-zero on regression
//!   --max-regress F      allowed fractional regression (default 0.20)
//! Each bench executes the same code path as the corresponding figure
//! harness on a reduced access budget and reports wall-clock plus
//! simulator throughput (accesses/sec).

mod harness;

use expand_cxl::config::{presets, Backing, MediaKind, PrefetcherKind, SimConfig, SsdConfig};
use expand_cxl::config::{InterleavePolicy, TopologySpec};
use expand_cxl::runtime::{AddressPredictor, Runtime, WindowInput};
use expand_cxl::sim::runner::simulate;
use expand_cxl::util::Rng;
use expand_cxl::workloads::apexmap::ApexMap;
use expand_cxl::workloads::mixed::{MixedTrace, WriteHeavy};
use expand_cxl::workloads::WorkloadId;
use harness::{bench_json, check_against_baseline, measure_throughput, Bench, Throughput};

const ACCESSES: usize = 60_000;

fn cfg() -> SimConfig {
    let mut c = presets::smoke();
    c.accesses = ACCESSES;
    c
}

fn run(c: &SimConfig, id: WorkloadId, rt: Option<&std::rc::Rc<Runtime>>) {
    let mut src = id.source(c.seed);
    simulate(c, rt, &mut *src).unwrap();
}

/// Bench CLI: `[filter] [--json-out P] [--check P] [--max-regress F]`.
struct BenchArgs {
    filter: Option<String>,
    json_out: Option<String>,
    check: Option<String>,
    max_regress: f64,
}

fn parse_args() -> BenchArgs {
    let mut out = BenchArgs {
        filter: None,
        json_out: None,
        check: None,
        max_regress: 0.20,
    };
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let a = args[i].clone();
        let take_value = |i: &mut usize| -> Option<String> {
            if let Some((_, v)) = args[*i].split_once('=') {
                return Some(v.to_string());
            }
            *i += 1;
            args.get(*i).cloned()
        };
        if a.starts_with("--json-out") {
            out.json_out = take_value(&mut i);
        } else if a.starts_with("--check") {
            out.check = take_value(&mut i);
        } else if a.starts_with("--max-regress") {
            out.max_regress = take_value(&mut i)
                .and_then(|v| v.parse().ok())
                .unwrap_or(0.20);
        } else if a == "--bench" || a.starts_with('-') {
            // cargo-injected or unknown flag: ignore.
        } else if out.filter.is_none() {
            out.filter = Some(a.clone());
        }
        i += 1;
    }
    out
}

/// The `runner_throughput` group: four end-to-end scenarios covering the
/// hot paths the allocation-free refactor targets — single-SSD chain
/// (ExPAND push path), a deep tree pool (per-endpoint routing +
/// deciders), a write-heavy 4-SSD pool (coherence/write path), and an
/// audited chain run (shadow-memory oracle riding along).
fn runner_throughput(b: &Bench) -> Vec<Throughput> {
    const THROUGHPUT_ITERS: usize = 5;
    let mut results = Vec::new();
    let mut scenario = |name: &str, c: SimConfig, write_boost: f64| {
        let full = format!("runner_throughput_{name}");
        if !b.enabled(&full) {
            return;
        }
        results.push(measure_throughput(&full, c.accesses as u64, THROUGHPUT_ITERS, || {
            if write_boost > 0.0 {
                let inner = WorkloadId::Pr.source(c.seed);
                let mut src = WriteHeavy::new(inner, write_boost, c.seed);
                simulate(&c, None, &mut src).unwrap();
            } else {
                run(&c, WorkloadId::Pr, None);
            }
        }));
    };

    // 1. Single CXL-SSD behind one switch (the seed chain), ExPAND.
    let mut c1 = cfg();
    c1.prefetcher = PrefetcherKind::Expand;
    scenario("chain_1ssd_expand", c1, 0.0);

    // 2. tree:2,2,4 — four endpoints behind two switch tiers.
    let mut c2 = cfg();
    c2.prefetcher = PrefetcherKind::Expand;
    c2.cxl.topology = TopologySpec::Tree { levels: 2, fanout: 2, ssds: 4 };
    scenario("tree_2_2_4_expand", c2, 0.0);

    // 3. Write-heavy 4-SSD pool, line-interleaved (coherence path hot).
    let mut c3 = cfg();
    c3.prefetcher = PrefetcherKind::Expand;
    c3.cxl.topology = TopologySpec::Tree { levels: 1, fanout: 2, ssds: 4 };
    c3.cxl.interleave = InterleavePolicy::Line;
    scenario("write_heavy_4ssd", c3, 0.3);

    // 4. Audited chain run: every read version-checked by the oracle.
    let mut c4 = cfg();
    c4.prefetcher = PrefetcherKind::Expand;
    c4.coherence.audit = true;
    scenario("audit_chain_expand", c4, 0.2);

    results
}

fn main() {
    let opts = parse_args();
    let mut b = Bench::with_filter(opts.filter.clone());
    let rt = if Runtime::artifacts_available("artifacts") {
        Some(Runtime::new("artifacts").unwrap())
    } else {
        eprintln!("note: no artifacts; ML benches use the mock predictor");
        None
    };

    // --- Fig 1: locality grid (LocalDRAM vs CXL-SSD, APEX-MAP) ---------
    b.bench("fig1_locality_grid", 3, || {
        for &(alpha, l) in &[(1.0, 4u64), (0.01, 64u64)] {
            for backing in [Backing::LocalDram, Backing::CxlSsd] {
                let mut c = cfg();
                c.backing = backing;
                let mut src = ApexMap::with_default_mem(Rng::new(1), alpha, l);
                simulate(&c, None, &mut src).unwrap();
            }
        }
    });

    // --- Fig 2a: effectiveness sweep -----------------------------------
    b.bench("fig2a_effectiveness_sweep", 3, || {
        for eff in [0.0, 0.5, 0.9, 1.0] {
            let mut c = cfg();
            c.prefetcher = PrefetcherKind::Synthetic { accuracy: eff, coverage: eff };
            run(&c, WorkloadId::Tc, None);
        }
    });

    // --- Fig 2c / Fig 6: switch-level sweeps ---------------------------
    b.bench("fig2c_fig6_switch_levels", 3, || {
        for lv in [0usize, 2, 4] {
            let mut c = cfg();
            c.cxl.switch_levels = lv;
            c.prefetcher = PrefetcherKind::Synthetic { accuracy: 0.9, coverage: 0.9 };
            run(&c, WorkloadId::Tc, None);
        }
    });

    // --- Table 1d / Fig 4a: the prefetcher comparison ------------------
    for kind in [
        PrefetcherKind::None,
        PrefetcherKind::Rule1,
        PrefetcherKind::Rule2,
        PrefetcherKind::Ml1,
        PrefetcherKind::Ml2,
        PrefetcherKind::Expand,
    ] {
        let name = format!("fig4a_prefetcher_{}", kind.name());
        let k = kind.clone();
        let rt2 = rt.clone();
        b.bench(&name, 3, move || {
            let mut c = cfg();
            c.prefetcher = k.clone();
            run(&c, WorkloadId::Pr, rt2.as_ref());
        });
    }

    // --- Fig 4b: mixed workloads ----------------------------------------
    b.bench("fig4b_mixed_expand", 3, || {
        let mut c = cfg();
        c.prefetcher = PrefetcherKind::Expand;
        let mut src = MixedTrace::new(&[WorkloadId::Cc, WorkloadId::Tc], c.seed);
        simulate(&c, rt.as_ref(), &mut src).unwrap();
    });

    // --- Fig 5: ExPAND vs LocalDRAM -------------------------------------
    b.bench("fig5_localdram_vs_expand", 3, || {
        let mut c = cfg();
        c.backing = Backing::LocalDram;
        run(&c, WorkloadId::Leslie3d, None);
        let mut c = cfg();
        c.prefetcher = PrefetcherKind::Expand;
        run(&c, WorkloadId::Leslie3d, rt.as_ref());
    });

    // --- Fig 7: backend media -------------------------------------------
    b.bench("fig7_backend_media", 3, || {
        for m in [MediaKind::ZNand, MediaKind::Pmem, MediaKind::Dram] {
            let mut c = cfg();
            let internal = c.ssd.internal_dram_bytes;
            c.ssd = SsdConfig::with_media(m);
            c.ssd.internal_dram_bytes = internal;
            c.prefetcher = PrefetcherKind::Expand;
            run(&c, WorkloadId::Tc, rt.as_ref());
        }
    });

    // --- End-to-end: runner_throughput group (tracked baseline) ---------
    let throughput = runner_throughput(&b);
    if throughput.is_empty() {
        if opts.check.is_some() {
            // An explicit regression gate must never pass vacuously
            // (e.g. a typo'd filter selecting zero scenarios).
            eprintln!("baseline check failed: filter selected no runner_throughput scenarios");
            std::process::exit(1);
        }
    } else {
        let json = bench_json("runner_throughput", &throughput);
        // Write where asked; without --json-out, only seed the default
        // repo-root baseline if it does not exist yet — never silently
        // clobber the tracked reference numbers (and their pre-PR
        // annotations) from a casual `cargo bench`.
        let default_path = "../BENCH_PR3.json";
        match &opts.json_out {
            Some(path) => match std::fs::write(path, &json) {
                Ok(()) => println!("wrote {path}"),
                Err(e) => eprintln!("warning: could not write {path}: {e}"),
            },
            None if !std::path::Path::new(default_path).exists() => {
                match std::fs::write(default_path, &json) {
                    Ok(()) => println!("wrote {default_path}"),
                    Err(e) => eprintln!("warning: could not write {default_path}: {e}"),
                }
            }
            None => {
                println!("{json}");
                println!(
                    "note: {default_path} exists; pass --json-out {default_path} to overwrite \
                     the tracked baseline"
                );
            }
        }
        if let Some(baseline_path) = &opts.check {
            match std::fs::read_to_string(baseline_path) {
                Ok(text) => match check_against_baseline(&text, &throughput, opts.max_regress) {
                    Ok(failures) if failures.is_empty() => {
                        println!(
                            "baseline check OK ({} scenarios, max regression {:.0}%)",
                            throughput.len(),
                            opts.max_regress * 100.0
                        );
                    }
                    Ok(failures) => {
                        for f in &failures {
                            eprintln!("REGRESSION: {f}");
                        }
                        std::process::exit(1);
                    }
                    Err(e) => {
                        eprintln!("baseline check failed: {e}");
                        std::process::exit(1);
                    }
                },
                Err(e) => {
                    eprintln!("baseline check failed: cannot read {baseline_path}: {e}");
                    std::process::exit(1);
                }
            }
        }
    }

    // --- Micro: simulator core throughput (events/s) ---------------------
    if b.enabled("micro_sim_throughput_noprefetch") {
        let mut c = cfg();
        c.accesses = 200_000;
        let t0 = std::time::Instant::now();
        run(&c, WorkloadId::Pr, None);
        let dt = t0.elapsed().as_secs_f64();
        b.report("micro_sim_throughput_noprefetch", c.accesses as f64 / dt, "accesses/s");
    }

    // --- Micro: predictor inference latency ------------------------------
    if let Some(rt) = &rt {
        for model in ["expand", "ml1", "ml2"] {
            let name = format!("micro_inference_{model}");
            if !b.enabled(&name) {
                continue;
            }
            let p = rt.predictor(model).unwrap();
            let shape = p.borrow().shape();
            let win = WindowInput {
                deltas: vec![65; shape.window],
                pcs: vec![3; shape.window],
                hint: 0.0,
            };
            let t0 = std::time::Instant::now();
            let iters = 100;
            for _ in 0..iters {
                p.borrow_mut().predict(std::slice::from_ref(&win)).unwrap();
            }
            let per = t0.elapsed().as_secs_f64() / iters as f64;
            b.report(&name, per * 1e6, "us/prediction");
        }
    }

    println!(
        "\n{} benches + {} throughput scenarios completed",
        b.results.len(),
        throughput.len()
    );
}
