//! Paper benches: one end-to-end bench per table/figure family plus the
//! micro-benches used by the §Perf optimization log in EXPERIMENTS.md.
//!
//! Run: `cargo bench` (optionally `cargo bench -- <filter>`). Each bench
//! executes the same code path as the corresponding figure harness on a
//! reduced access budget and reports wall-clock, plus simulator
//! throughput metrics.

mod harness;

use expand_cxl::config::{presets, Backing, MediaKind, PrefetcherKind, SimConfig, SsdConfig};
use expand_cxl::runtime::{AddressPredictor, Runtime, WindowInput};
use expand_cxl::sim::runner::simulate;
use expand_cxl::util::Rng;
use expand_cxl::workloads::apexmap::ApexMap;
use expand_cxl::workloads::mixed::MixedTrace;
use expand_cxl::workloads::WorkloadId;
use harness::Bench;

const ACCESSES: usize = 60_000;

fn cfg() -> SimConfig {
    let mut c = presets::smoke();
    c.accesses = ACCESSES;
    c
}

fn run(c: &SimConfig, id: WorkloadId, rt: Option<&std::rc::Rc<Runtime>>) {
    let mut src = id.source(c.seed);
    simulate(c, rt, &mut *src).unwrap();
}

fn main() {
    let mut b = Bench::from_args();
    let rt = if Runtime::artifacts_available("artifacts") {
        Some(Runtime::new("artifacts").unwrap())
    } else {
        eprintln!("note: no artifacts; ML benches use the mock predictor");
        None
    };

    // --- Fig 1: locality grid (LocalDRAM vs CXL-SSD, APEX-MAP) ---------
    b.bench("fig1_locality_grid", 3, || {
        for &(alpha, l) in &[(1.0, 4u64), (0.01, 64u64)] {
            for backing in [Backing::LocalDram, Backing::CxlSsd] {
                let mut c = cfg();
                c.backing = backing;
                let mut src = ApexMap::with_default_mem(Rng::new(1), alpha, l);
                simulate(&c, None, &mut src).unwrap();
            }
        }
    });

    // --- Fig 2a: effectiveness sweep -----------------------------------
    b.bench("fig2a_effectiveness_sweep", 3, || {
        for eff in [0.0, 0.5, 0.9, 1.0] {
            let mut c = cfg();
            c.prefetcher = PrefetcherKind::Synthetic { accuracy: eff, coverage: eff };
            run(&c, WorkloadId::Tc, None);
        }
    });

    // --- Fig 2c / Fig 6: switch-level sweeps ---------------------------
    b.bench("fig2c_fig6_switch_levels", 3, || {
        for lv in [0usize, 2, 4] {
            let mut c = cfg();
            c.cxl.switch_levels = lv;
            c.prefetcher = PrefetcherKind::Synthetic { accuracy: 0.9, coverage: 0.9 };
            run(&c, WorkloadId::Tc, None);
        }
    });

    // --- Table 1d / Fig 4a: the prefetcher comparison ------------------
    for kind in [
        PrefetcherKind::None,
        PrefetcherKind::Rule1,
        PrefetcherKind::Rule2,
        PrefetcherKind::Ml1,
        PrefetcherKind::Ml2,
        PrefetcherKind::Expand,
    ] {
        let name = format!("fig4a_prefetcher_{}", kind.name());
        let k = kind.clone();
        let rt2 = rt.clone();
        b.bench(&name, 3, move || {
            let mut c = cfg();
            c.prefetcher = k.clone();
            run(&c, WorkloadId::Pr, rt2.as_ref());
        });
    }

    // --- Fig 4b: mixed workloads ----------------------------------------
    b.bench("fig4b_mixed_expand", 3, || {
        let mut c = cfg();
        c.prefetcher = PrefetcherKind::Expand;
        let mut src = MixedTrace::new(&[WorkloadId::Cc, WorkloadId::Tc], c.seed);
        simulate(&c, rt.as_ref(), &mut src).unwrap();
    });

    // --- Fig 5: ExPAND vs LocalDRAM -------------------------------------
    b.bench("fig5_localdram_vs_expand", 3, || {
        let mut c = cfg();
        c.backing = Backing::LocalDram;
        run(&c, WorkloadId::Leslie3d, None);
        let mut c = cfg();
        c.prefetcher = PrefetcherKind::Expand;
        run(&c, WorkloadId::Leslie3d, rt.as_ref());
    });

    // --- Fig 7: backend media -------------------------------------------
    b.bench("fig7_backend_media", 3, || {
        for m in [MediaKind::ZNand, MediaKind::Pmem, MediaKind::Dram] {
            let mut c = cfg();
            let internal = c.ssd.internal_dram_bytes;
            c.ssd = SsdConfig::with_media(m);
            c.ssd.internal_dram_bytes = internal;
            c.prefetcher = PrefetcherKind::Expand;
            run(&c, WorkloadId::Tc, rt.as_ref());
        }
    });

    // --- Micro: simulator core throughput (events/s) ---------------------
    {
        let mut c = cfg();
        c.accesses = 200_000;
        let t0 = std::time::Instant::now();
        run(&c, WorkloadId::Pr, None);
        let dt = t0.elapsed().as_secs_f64();
        b.report("micro_sim_throughput_noprefetch", c.accesses as f64 / dt, "accesses/s");
    }

    // --- Micro: predictor inference latency ------------------------------
    if let Some(rt) = &rt {
        for model in ["expand", "ml1", "ml2"] {
            let p = rt.predictor(model).unwrap();
            let shape = p.borrow().shape();
            let win = WindowInput {
                deltas: vec![65; shape.window],
                pcs: vec![3; shape.window],
                hint: 0.0,
            };
            let t0 = std::time::Instant::now();
            let iters = 100;
            for _ in 0..iters {
                p.borrow_mut().predict(std::slice::from_ref(&win)).unwrap();
            }
            let per = t0.elapsed().as_secs_f64() / iters as f64;
            b.report(
                &format!("micro_inference_{model}"),
                per * 1e6,
                "us/prediction",
            );
        }
    }

    println!("\n{} benches completed", b.results.len());
}
