//! Paper benches: one end-to-end bench per table/figure family, the
//! micro-benches used by the §Perf optimization log, and five tracked
//! throughput groups — `runner_throughput` (four single-host scenarios,
//! `BENCH_PR3.json`), `multi_host_scaling` (the epoch-quantized
//! multi-host engine at 1 vs 4 worker threads, `BENCH_PR4.json`),
//! `trace_replay` (trace capture/replay vs synthetic generation,
//! `BENCH_PR5.json`), `batched_hot_loop` (the batched SIMD-friendly
//! hot loop + mmap zero-copy replay, `BENCH_PR6.json`) and
//! `fleet_scaling` (the hierarchical fleet engine at 256 multiplexed
//! hosts, `BENCH_PR9.json`). CI fails on >20% regression against any
//! committed baseline.
//!
//! Run: `cargo bench` (optionally `cargo bench -- <filter>`). Flags
//! after the filter:
//!   --json-out PATH      write runner_throughput results as JSON
//!                        (default ../BENCH_PR3.json when seeding)
//!   --check PATH         gate runner_throughput against a baseline
//!   --mh-json-out PATH   write multi_host_scaling results as JSON
//!                        (default ../BENCH_PR4.json when seeding)
//!   --mh-check PATH      gate multi_host_scaling against a baseline
//!   --tr-json-out PATH   write trace_replay results as JSON
//!                        (default ../BENCH_PR5.json when seeding)
//!   --tr-check PATH      gate trace_replay against a baseline
//!   --b6-json-out PATH   write batched_hot_loop results as JSON
//!                        (default ../BENCH_PR6.json when seeding)
//!   --b6-check PATH      gate batched_hot_loop against a baseline
//!   --fl-json-out PATH   write fleet_scaling results as JSON
//!                        (default ../BENCH_PR9.json when seeding)
//!   --fl-check PATH      gate fleet_scaling against a baseline
//!   --max-regress F      allowed fractional regression (default 0.20)
//! Baseline rewrites preserve hand-recorded annotations (`note`,
//! pre-PR reference numbers) and stamp the measuring `machine`
//! automatically. Each bench executes the same code path as the
//! corresponding figure harness on a reduced access budget and reports
//! wall-clock plus simulator throughput (accesses/sec).

mod harness;

use expand_cxl::config::{presets, Backing, MediaKind, PrefetcherKind, SimConfig, SsdConfig};
use expand_cxl::config::{InterleavePolicy, TopologySpec};
use expand_cxl::obs::ObsOptions;
use expand_cxl::runtime::{AddressPredictor, Runtime, WindowInput};
use expand_cxl::sim::parallel::{run_multi_host_workload, MultiHostOpts};
use expand_cxl::sim::runner::{simulate, Runner};
use expand_cxl::trace::{write_trace, TraceReplay};
use expand_cxl::util::json::{self, Json};
use expand_cxl::util::Rng;
use expand_cxl::workloads::apexmap::ApexMap;
use expand_cxl::workloads::mixed::{MixedTrace, WriteHeavy};
use expand_cxl::workloads::WorkloadId;
use harness::{bench_json, check_against_baseline, measure_throughput, Bench, Throughput};

const ACCESSES: usize = 60_000;

fn cfg() -> SimConfig {
    let mut c = presets::smoke();
    c.accesses = ACCESSES;
    c
}

fn run(c: &SimConfig, id: WorkloadId, rt: Option<&std::rc::Rc<Runtime>>) {
    let mut src = id.source(c.seed);
    simulate(&std::sync::Arc::new(c.clone()), rt, &mut *src).unwrap();
}

/// Bench CLI: `[filter] [--json-out P] [--check P] [--mh-json-out P]
/// [--mh-check P] [--max-regress F]`. The `mh-` pair addresses the
/// `multi_host_scaling` group's tracked file (BENCH_PR4.json); the
/// plain pair addresses `runner_throughput` (BENCH_PR3.json).
struct BenchArgs {
    filter: Option<String>,
    json_out: Option<String>,
    check: Option<String>,
    mh_json_out: Option<String>,
    mh_check: Option<String>,
    tr_json_out: Option<String>,
    tr_check: Option<String>,
    b6_json_out: Option<String>,
    b6_check: Option<String>,
    fl_json_out: Option<String>,
    fl_check: Option<String>,
    max_regress: f64,
}

fn parse_args() -> BenchArgs {
    let mut out = BenchArgs {
        filter: None,
        json_out: None,
        check: None,
        mh_json_out: None,
        mh_check: None,
        tr_json_out: None,
        tr_check: None,
        b6_json_out: None,
        b6_check: None,
        fl_json_out: None,
        fl_check: None,
        max_regress: 0.20,
    };
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let a = args[i].clone();
        let take_value = |i: &mut usize| -> Option<String> {
            if let Some((_, v)) = args[*i].split_once('=') {
                return Some(v.to_string());
            }
            *i += 1;
            args.get(*i).cloned()
        };
        if a.starts_with("--json-out") {
            out.json_out = take_value(&mut i);
        } else if a.starts_with("--mh-json-out") {
            out.mh_json_out = take_value(&mut i);
        } else if a.starts_with("--mh-check") {
            out.mh_check = take_value(&mut i);
        } else if a.starts_with("--tr-json-out") {
            out.tr_json_out = take_value(&mut i);
        } else if a.starts_with("--tr-check") {
            out.tr_check = take_value(&mut i);
        } else if a.starts_with("--b6-json-out") {
            out.b6_json_out = take_value(&mut i);
        } else if a.starts_with("--b6-check") {
            out.b6_check = take_value(&mut i);
        } else if a.starts_with("--fl-json-out") {
            out.fl_json_out = take_value(&mut i);
        } else if a.starts_with("--fl-check") {
            out.fl_check = take_value(&mut i);
        } else if a.starts_with("--check") {
            out.check = take_value(&mut i);
        } else if a.starts_with("--max-regress") {
            out.max_regress = take_value(&mut i)
                .and_then(|v| v.parse().ok())
                .unwrap_or(0.20);
        } else if a == "--bench" || a.starts_with('-') {
            // cargo-injected or unknown flag: ignore.
        } else if out.filter.is_none() {
            out.filter = Some(a.clone());
        }
        i += 1;
    }
    out
}

/// Write one bench group's JSON (annotations preserved, `machine`
/// auto-emitted) and gate it against a committed baseline. Returns
/// `false` on a regression or an unusable baseline.
fn publish_group(
    suite: &str,
    results: &[Throughput],
    json_out: Option<&String>,
    check: Option<&String>,
    default_path: &str,
    max_regress: f64,
    annotate: impl FnOnce(&mut Json),
) -> bool {
    if results.is_empty() {
        if check.is_some() {
            // An explicit regression gate must never pass vacuously
            // (e.g. a typo'd filter selecting zero scenarios).
            eprintln!("baseline check failed: filter selected no {suite} scenarios");
            return false;
        }
        return true;
    }
    // Annotation source, in preference order: the destination file
    // itself, the committed default baseline, the --check baseline.
    let prior_text = [json_out.map(String::as_str), Some(default_path), check.map(String::as_str)]
        .into_iter()
        .flatten()
        .find(|p| std::path::Path::new(p).exists())
        .and_then(|p| std::fs::read_to_string(p).ok());
    let text = bench_json(suite, results, prior_text.as_deref());
    let rendered = match json::parse(&text) {
        Ok(mut doc) => {
            annotate(&mut doc);
            json::render(&doc)
        }
        Err(_) => text,
    };
    match json_out {
        Some(path) => match std::fs::write(path, &rendered) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("warning: could not write {path}: {e}"),
        },
        // Without an explicit destination, only seed the tracked
        // repo-root baseline if it does not exist yet — never silently
        // clobber committed reference numbers from a casual run.
        None if !std::path::Path::new(default_path).exists() => {
            match std::fs::write(default_path, &rendered) {
                Ok(()) => println!("wrote {default_path}"),
                Err(e) => eprintln!("warning: could not write {default_path}: {e}"),
            }
        }
        None => {
            println!("{rendered}");
            println!(
                "note: {default_path} exists; pass --json-out {default_path} (or \
                 --mh-json-out for the multi-host group) to overwrite the tracked baseline"
            );
        }
    }
    let Some(baseline_path) = check else { return true };
    match std::fs::read_to_string(baseline_path) {
        Ok(text) => match check_against_baseline(&text, results, max_regress) {
            Ok(failures) if failures.is_empty() => {
                println!(
                    "baseline check OK ({} scenarios, max regression {:.0}%)",
                    results.len(),
                    max_regress * 100.0
                );
                true
            }
            Ok(failures) => {
                for f in &failures {
                    eprintln!("REGRESSION: {f}");
                }
                false
            }
            Err(e) => {
                eprintln!("baseline check failed: {e}");
                false
            }
        },
        Err(e) => {
            eprintln!("baseline check failed: cannot read {baseline_path}: {e}");
            false
        }
    }
}

/// The `runner_throughput` group: four end-to-end scenarios covering the
/// hot paths the allocation-free refactor targets — single-SSD chain
/// (ExPAND push path), a deep tree pool (per-endpoint routing +
/// deciders), a write-heavy 4-SSD pool (coherence/write path), and an
/// audited chain run (shadow-memory oracle riding along).
fn runner_throughput(b: &Bench) -> Vec<Throughput> {
    const THROUGHPUT_ITERS: usize = 5;
    let mut results = Vec::new();
    let mut scenario = |name: &str, c: SimConfig, write_boost: f64| {
        let full = format!("runner_throughput_{name}");
        if !b.enabled(&full) {
            return;
        }
        let c = std::sync::Arc::new(c);
        results.push(measure_throughput(&full, c.accesses as u64, THROUGHPUT_ITERS, || {
            if write_boost > 0.0 {
                let inner = WorkloadId::Pr.source(c.seed);
                let mut src = WriteHeavy::new(inner, write_boost, c.seed);
                simulate(&c, None, &mut src).unwrap();
            } else {
                let mut src = WorkloadId::Pr.source(c.seed);
                simulate(&c, None, &mut *src).unwrap();
            }
        }));
    };

    // 1. Single CXL-SSD behind one switch (the seed chain), ExPAND.
    let mut c1 = cfg();
    c1.prefetcher = PrefetcherKind::Expand;
    scenario("chain_1ssd_expand", c1, 0.0);

    // 2. tree:2,2,4 — four endpoints behind two switch tiers.
    let mut c2 = cfg();
    c2.prefetcher = PrefetcherKind::Expand;
    c2.cxl.topology = TopologySpec::Tree { levels: 2, fanout: 2, ssds: 4 };
    scenario("tree_2_2_4_expand", c2, 0.0);

    // 3. Write-heavy 4-SSD pool, line-interleaved (coherence path hot).
    let mut c3 = cfg();
    c3.prefetcher = PrefetcherKind::Expand;
    c3.cxl.topology = TopologySpec::Tree { levels: 1, fanout: 2, ssds: 4 };
    c3.cxl.interleave = InterleavePolicy::Line;
    scenario("write_heavy_4ssd", c3, 0.3);

    // 4. Audited chain run: every read version-checked by the oracle.
    let mut c4 = cfg();
    c4.prefetcher = PrefetcherKind::Expand;
    c4.coherence.audit = true;
    scenario("audit_chain_expand", c4, 0.2);

    results
}

/// The `multi_host_scaling` group (tracked in `BENCH_PR4.json`): the
/// epoch-quantized multi-host engine on a 4-host / 4-SSD shared pool.
/// The pair of scenarios measures aggregate accesses/sec with the same
/// 4 host streams executed on 1 worker thread (the sequential
/// reference) and on 4 worker threads; their ratio is the engine's
/// scaling headline (bit-identical results either way — only wall
/// clock differs). Returns the scenarios plus the measured speedup.
fn multi_host_scaling(b: &Bench) -> (Vec<Throughput>, Option<f64>) {
    const ITERS: usize = 3;
    const HOSTS: usize = 4;
    let mut results = Vec::new();
    let base = {
        let mut c = cfg();
        c.accesses = 40_000;
        c.prefetcher = PrefetcherKind::Expand;
        c.cxl.topology = TopologySpec::Tree { levels: 1, fanout: 2, ssds: 4 };
        std::sync::Arc::new(c)
    };
    let mut thr = |name: &str, threads: usize| -> Option<f64> {
        let full = format!("multi_host_scaling_{name}");
        if !b.enabled(&full) {
            return None;
        }
        let opts = MultiHostOpts {
            hosts: HOSTS,
            threads,
            epoch_accesses: 4096,
            ..MultiHostOpts::default()
        };
        let total = (base.accesses * HOSTS) as u64;
        let t = measure_throughput(&full, total, ITERS, || {
            let s = run_multi_host_workload(&base, &opts, WorkloadId::Pr).unwrap();
            assert!(s.bi_invariant, "shared BI-directory invariant violated in bench");
        });
        let aps = t.mean_accesses_per_sec;
        results.push(t);
        Some(aps)
    };
    let serial = thr("hosts4_threads1", 1);
    let parallel = thr("hosts4_threads4", HOSTS);
    let speedup = match (serial, parallel) {
        (Some(a), Some(p)) if a > 0.0 => Some(p / a),
        _ => None,
    };
    if let Some(s) = speedup {
        println!(
            "multi-host scaling: threads4/threads1 = {s:.2}x on {} cores \
             (target >=3x with >=4 cores)",
            expand_cxl::util::default_parallelism()
        );
    }
    (results, speedup)
}

/// The `fleet_scaling` group (tracked in `BENCH_PR9.json`): the
/// hierarchical fleet engine at 256 multiplexed hosts on a shared
/// 4-SSD pool. Four scenarios: the 256-host run on 1 worker thread
/// (the sequential reference for the whole merge tree), the same run
/// on every available core (threads auto — the headline), the
/// all-core run with an 8-tenant diurnal fleet mix riding along (the
/// tenant SLO rollup's cost), and the all-core run with the engine
/// self-profiler disabled (the profiler overhead guard — the profiler
/// is on by default everywhere else). The serial and all-core runs
/// must produce bit-identical fingerprints — asserted here, on every
/// iteration, profiler on or off — and the annotated headlines are
/// per-core scaling efficiency `(aps_all / aps_1) / cores` (acceptance
/// floor 0.7) and the profiler on/off throughput ratio (target >=0.98,
/// i.e. <=2% overhead; hard floor 0.90 to absorb wall-clock noise).
fn fleet_scaling(b: &Bench) -> (Vec<Throughput>, Option<f64>, Option<f64>) {
    const ITERS: usize = 2;
    const HOSTS: usize = 256;
    let mut results = Vec::new();
    let base = {
        let mut c = cfg();
        c.accesses = 2_000; // per host: 512k fleet accesses per iteration
        c.prefetcher = PrefetcherKind::Expand;
        c.cxl.topology = TopologySpec::Tree { levels: 1, fanout: 2, ssds: 4 };
        std::sync::Arc::new(c)
    };
    let cores = expand_cxl::util::default_parallelism().min(HOSTS).max(1);

    let mut thr = |name: &str,
                   threads: usize,
                   fleet: Option<&str>,
                   profile: bool|
     -> Option<(f64, String)> {
        let full = format!("fleet_scaling_{name}");
        if !b.enabled(&full) {
            return None;
        }
        let opts = MultiHostOpts {
            hosts: HOSTS,
            threads,
            epoch_accesses: 1024,
            fleet: fleet.map(|s| {
                expand_cxl::workloads::fleet::FleetSpec::parse(s).unwrap()
            }),
            profile,
            ..MultiHostOpts::default()
        };
        let total = (base.accesses * HOSTS) as u64;
        let mut fp = String::new();
        let t = measure_throughput(&full, total, ITERS, || {
            let s = run_multi_host_workload(&base, &opts, WorkloadId::Pr).unwrap();
            assert!(s.bi_invariant, "BI-directory invariant violated at fleet scale");
            fp = s.fingerprint();
        });
        let aps = t.mean_accesses_per_sec;
        results.push(t);
        Some((aps, fp))
    };

    let serial = thr("hosts256_threads1", 1, None, true);
    let wide = thr("hosts256_threads_all", 0, None, true);
    let _mix = thr(
        "hosts256_fleet_mix",
        0,
        Some("tenants=8,skew=100,shape=diurnal,period=8192,peak=4,arrival=2048"),
        true,
    );
    let profile_off = thr("hosts256_profile_off", 0, None, false);

    if let (Some((_, f1)), Some((_, fw))) = (&serial, &wide) {
        assert_eq!(
            f1, fw,
            "threads-1 and all-core fleet runs must be bit-identical"
        );
        println!("fleet scaling: 256-host fingerprint identical at 1 and {cores} threads");
    }
    if let (Some((_, fw)), Some((_, fo))) = (&wide, &profile_off) {
        assert_eq!(
            fw, fo,
            "the engine self-profiler must never perturb the fingerprint"
        );
    }
    let efficiency = match (&serial, &wide) {
        (Some((a, _)), Some((p, _))) if *a > 0.0 => Some((p / a) / cores as f64),
        _ => None,
    };
    if let Some(e) = efficiency {
        println!(
            "fleet scaling: per-core efficiency = {e:.2}x on {cores} cores (target >=0.7x)"
        );
    }
    // Profiler overhead guard: the all-core run with phase timers on vs
    // off. The timer cost is a handful of monotonic-clock reads per
    // worker per epoch, so the ratio should be ~1.0 (target >=0.98);
    // the hard floor leaves room for wall-clock noise on busy CI boxes.
    let profiler_ratio = match (&wide, &profile_off) {
        (Some((on, _)), Some((off, _))) if *off > 0.0 => Some(on / off),
        _ => None,
    };
    if let Some(r) = profiler_ratio {
        println!(
            "fleet scaling: profiler on/off throughput ratio = {r:.3} \
             (target >=0.98, <=2% overhead)"
        );
        assert!(r >= 0.90, "engine self-profiler overhead above 10%: ratio {r:.3}");
    }
    (results, efficiency, profiler_ratio)
}

/// The `trace_replay` group (tracked in `BENCH_PR5.json`): trace
/// subsystem throughput on the chain ExPAND scenario. Three scenarios
/// share one configuration — synthetic generation (the reference every
/// trace-driven run competes with), record (the same run with capture
/// enabled plus the binary write), and replay (open + decode + replay
/// from the file). Replay has no generation cost, so it is expected to
/// be at least as fast as synthetic generation.
fn trace_replay(b: &Bench) -> Vec<Throughput> {
    const ITERS: usize = 5;
    let mut results = Vec::new();
    let base = {
        let mut c = cfg();
        c.prefetcher = PrefetcherKind::Expand;
        std::sync::Arc::new(c)
    };
    let path = std::env::temp_dir()
        .join(format!("expand_bench_{}.trace", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let record_once = || {
        let mut r = Runner::new(&base, None).unwrap();
        r.enable_recording();
        let mut src = WorkloadId::Pr.source(base.seed);
        let stats = r.run(&mut *src, base.accesses);
        write_trace(&path, &stats.workload, base.seed, &[r.take_recording()]).unwrap();
    };

    let gen_name = "trace_replay_synthetic_gen";
    if b.enabled(gen_name) {
        results.push(measure_throughput(gen_name, base.accesses as u64, ITERS, || {
            let mut src = WorkloadId::Pr.source(base.seed);
            simulate(&base, None, &mut *src).unwrap();
        }));
    }
    let rec_name = "trace_replay_record";
    if b.enabled(rec_name) {
        results.push(measure_throughput(rec_name, base.accesses as u64, ITERS, || {
            record_once();
        }));
    }
    let rep_name = "trace_replay_replay";
    if b.enabled(rep_name) {
        if !std::path::Path::new(&path).exists() {
            record_once(); // setup only (the record scenario was filtered out)
        }
        results.push(measure_throughput(rep_name, base.accesses as u64, ITERS, || {
            let mut src = TraceReplay::open(&path).unwrap();
            simulate(&base, None, &mut src).unwrap();
        }));
    }
    let _ = std::fs::remove_file(&path);
    results
}

/// The `batched_hot_loop` group (tracked in `BENCH_PR6.json`): the
/// batched SoA hot loop and the mmap-backed zero-copy replay path, at
/// the default `[sim] batch = 256`. Four scenarios: the single-SSD
/// chain (the >10M accesses/s single-threaded headline), the tree
/// pool (batch route pass over four endpoints), a write-heavy
/// line-interleaved pool (coherence path under batching), and replay
/// of a recorded chain run decoded batch-at-a-time straight from the
/// mapping — no generation cost, no materialized record Vec. Returns
/// the scenarios plus the replay-vs-synthetic ratio (acceptance floor
/// 1.5x), computed against this group's own chain scenario so both
/// sides of the ratio come from the same build and budget.
///
/// The group also carries the observability overhead guard: the chain
/// run measured with the obs recorder off and on (`obs_overhead_off` /
/// `obs_overhead_on`). The off side rides the same gated group as the
/// chain scenario; the on side must stay within 10% of off — enforced
/// with a hard assert, and the ratio is annotated into the tracked
/// JSON. Third return value: obs on/off throughput ratio.
///
/// A parallel guard covers fault injection (`fault_off` /
/// `fault_idle`): the chain run with no fault state at all vs an
/// enabled-but-idle schedule whose draws happen on every miss and fill
/// but ~never fire. Idle must stay within 2% of off — the hot loop may
/// not pay for robustness it isn't using. Fourth return value:
/// idle/off throughput ratio.
fn batched_hot_loop(b: &Bench) -> (Vec<Throughput>, Option<f64>, Option<f64>, Option<f64>) {
    const ITERS: usize = 5;
    let mut results = Vec::new();
    let mut scenario = |name: &str, c: SimConfig, write_boost: f64| -> Option<f64> {
        let full = format!("batched_hot_loop_{name}");
        if !b.enabled(&full) {
            return None;
        }
        let c = std::sync::Arc::new(c);
        let t = measure_throughput(&full, c.accesses as u64, ITERS, || {
            if write_boost > 0.0 {
                let inner = WorkloadId::Pr.source(c.seed);
                let mut src = WriteHeavy::new(inner, write_boost, c.seed);
                simulate(&c, None, &mut src).unwrap();
            } else {
                let mut src = WorkloadId::Pr.source(c.seed);
                simulate(&c, None, &mut *src).unwrap();
            }
        });
        let aps = t.mean_accesses_per_sec;
        results.push(t);
        Some(aps)
    };

    let mut c1 = cfg();
    c1.prefetcher = PrefetcherKind::Expand;
    let chain_aps = scenario("chain_1ssd_expand", c1, 0.0);

    let mut c2 = cfg();
    c2.prefetcher = PrefetcherKind::Expand;
    c2.cxl.topology = TopologySpec::Tree { levels: 2, fanout: 2, ssds: 4 };
    scenario("tree_2_2_4_expand", c2, 0.0);

    let mut c3 = cfg();
    c3.prefetcher = PrefetcherKind::Expand;
    c3.cxl.topology = TopologySpec::Tree { levels: 1, fanout: 2, ssds: 4 };
    c3.cxl.interleave = InterleavePolicy::Line;
    scenario("write_heavy_4ssd", c3, 0.3);

    // Zero-copy replay: record the chain run once (setup, not timed),
    // then measure replay-from-mmap of the same access stream.
    let mut replay_aps: Option<f64> = None;
    let rep_name = "batched_hot_loop_replay_mmap_chain";
    if b.enabled(rep_name) {
        let base = {
            let mut c = cfg();
            c.prefetcher = PrefetcherKind::Expand;
            std::sync::Arc::new(c)
        };
        let path = std::env::temp_dir()
            .join(format!("expand_bench_b6_{}.trace", std::process::id()))
            .to_string_lossy()
            .into_owned();
        {
            let mut r = Runner::new(&base, None).unwrap();
            r.enable_recording();
            let mut src = WorkloadId::Pr.source(base.seed);
            let stats = r.run(&mut *src, base.accesses);
            write_trace(&path, &stats.workload, base.seed, &[r.take_recording()]).unwrap();
        }
        let t = measure_throughput(rep_name, base.accesses as u64, ITERS, || {
            let mut src = TraceReplay::open(&path).unwrap();
            simulate(&base, None, &mut src).unwrap();
        });
        replay_aps = Some(t.mean_accesses_per_sec);
        results.push(t);
        let _ = std::fs::remove_file(&path);
    }

    let ratio = match (chain_aps, replay_aps) {
        (Some(c), Some(r)) if c > 0.0 => Some(r / c),
        _ => None,
    };
    if let Some(r) = ratio {
        println!("batched hot loop: replay_mmap/synthetic_chain = {r:.2}x (target >=1.5x)");
    }

    // Observability overhead guard: the identical chain run through the
    // Runner with the recorder disabled and enabled. Disabled is one
    // well-predicted `is_some` branch per site; enabled is O(1)
    // histogram bumps plus a capacity-bounded event ring.
    let mut obs_ratio: Option<f64> = None;
    {
        let base = {
            let mut c = cfg();
            c.prefetcher = PrefetcherKind::Expand;
            std::sync::Arc::new(c)
        };
        let mut obs_run = |name: &str, obs: bool| -> Option<f64> {
            let full = format!("batched_hot_loop_{name}");
            if !b.enabled(&full) {
                return None;
            }
            let t = measure_throughput(&full, base.accesses as u64, ITERS, || {
                let mut r = Runner::new(&base, None).unwrap();
                if obs {
                    r.enable_obs(ObsOptions {
                        series_stride: 4096,
                        trace_events: true,
                        ..ObsOptions::default()
                    });
                }
                let mut src = WorkloadId::Pr.source(base.seed);
                let stats = r.run(&mut *src, base.accesses);
                if obs {
                    assert!(stats.obs.is_some(), "enabled recorder must surface a summary");
                }
            });
            let aps = t.mean_accesses_per_sec;
            results.push(t);
            Some(aps)
        };
        let off = obs_run("obs_overhead_off", false);
        let on = obs_run("obs_overhead_on", true);
        if let (Some(off), Some(on)) = (off, on) {
            let r = on / off;
            obs_ratio = Some(r);
            println!("batched hot loop: obs_on/obs_off = {r:.2}x (floor 0.90x)");
            assert!(r >= 0.90, "observability overhead exceeds 10%: on/off = {r:.3}x");
        }
    }

    // Fault-path overhead guard: the identical chain run with fault
    // injection fully disabled (no fault state — one well-predicted
    // `is_some` branch per site) and enabled-but-idle (probabilities so
    // small every miss and fill draws but ~never hits).
    let mut fault_ratio: Option<f64> = None;
    {
        let mut fault_run = |name: &str, spec: Option<&str>| -> Option<f64> {
            let full = format!("batched_hot_loop_{name}");
            if !b.enabled(&full) {
                return None;
            }
            let base = {
                let mut c = cfg();
                c.prefetcher = PrefetcherKind::Expand;
                if let Some(s) = spec {
                    c.fault = expand_cxl::fault::FaultConfig::parse(s).unwrap();
                }
                std::sync::Arc::new(c)
            };
            let t = measure_throughput(&full, base.accesses as u64, ITERS, || {
                let mut src = WorkloadId::Pr.source(base.seed);
                let s = simulate(&base, None, &mut *src).unwrap();
                if spec.is_some() {
                    assert_eq!(
                        s.link_retries + s.poison_drops,
                        0,
                        "idle schedule must not actually fire"
                    );
                }
            });
            let aps = t.mean_accesses_per_sec;
            results.push(t);
            Some(aps)
        };
        let off = fault_run("fault_off", None);
        let idle = fault_run("fault_idle", Some("link_crc=1e-18,poison=1e-18"));
        if let (Some(off), Some(idle)) = (off, idle) {
            let r = idle / off;
            fault_ratio = Some(r);
            println!("batched hot loop: fault_idle/fault_off = {r:.2}x (floor 0.98x)");
            assert!(r >= 0.98, "fault path costs more than 2% when idle: idle/off = {r:.3}x");
        }
    }
    (results, ratio, obs_ratio, fault_ratio)
}

fn main() {
    let opts = parse_args();
    let mut b = Bench::with_filter(opts.filter.clone());
    let rt = if Runtime::artifacts_available("artifacts") {
        Some(Runtime::new("artifacts").unwrap())
    } else {
        eprintln!("note: no artifacts; ML benches use the mock predictor");
        None
    };

    // --- Fig 1: locality grid (LocalDRAM vs CXL-SSD, APEX-MAP) ---------
    b.bench("fig1_locality_grid", 3, || {
        for &(alpha, l) in &[(1.0, 4u64), (0.01, 64u64)] {
            for backing in [Backing::LocalDram, Backing::CxlSsd] {
                let mut c = cfg();
                c.backing = backing;
                let mut src = ApexMap::with_default_mem(Rng::new(1), alpha, l);
                simulate(&std::sync::Arc::new(c), None, &mut src).unwrap();
            }
        }
    });

    // --- Fig 2a: effectiveness sweep -----------------------------------
    b.bench("fig2a_effectiveness_sweep", 3, || {
        for eff in [0.0, 0.5, 0.9, 1.0] {
            let mut c = cfg();
            c.prefetcher = PrefetcherKind::Synthetic { accuracy: eff, coverage: eff };
            run(&c, WorkloadId::Tc, None);
        }
    });

    // --- Fig 2c / Fig 6: switch-level sweeps ---------------------------
    b.bench("fig2c_fig6_switch_levels", 3, || {
        for lv in [0usize, 2, 4] {
            let mut c = cfg();
            c.cxl.switch_levels = lv;
            c.prefetcher = PrefetcherKind::Synthetic { accuracy: 0.9, coverage: 0.9 };
            run(&c, WorkloadId::Tc, None);
        }
    });

    // --- Table 1d / Fig 4a: the prefetcher comparison ------------------
    for kind in [
        PrefetcherKind::None,
        PrefetcherKind::Rule1,
        PrefetcherKind::Rule2,
        PrefetcherKind::Ml1,
        PrefetcherKind::Ml2,
        PrefetcherKind::Expand,
    ] {
        let name = format!("fig4a_prefetcher_{}", kind.name());
        let k = kind.clone();
        let rt2 = rt.clone();
        b.bench(&name, 3, move || {
            let mut c = cfg();
            c.prefetcher = k.clone();
            run(&c, WorkloadId::Pr, rt2.as_ref());
        });
    }

    // --- Fig 4b: mixed workloads ----------------------------------------
    b.bench("fig4b_mixed_expand", 3, || {
        let mut c = cfg();
        c.prefetcher = PrefetcherKind::Expand;
        let mut src = MixedTrace::new(&[WorkloadId::Cc, WorkloadId::Tc], c.seed);
        simulate(&std::sync::Arc::new(c), rt.as_ref(), &mut src).unwrap();
    });

    // --- Fig 5: ExPAND vs LocalDRAM -------------------------------------
    b.bench("fig5_localdram_vs_expand", 3, || {
        let mut c = cfg();
        c.backing = Backing::LocalDram;
        run(&c, WorkloadId::Leslie3d, None);
        let mut c = cfg();
        c.prefetcher = PrefetcherKind::Expand;
        run(&c, WorkloadId::Leslie3d, rt.as_ref());
    });

    // --- Fig 7: backend media -------------------------------------------
    b.bench("fig7_backend_media", 3, || {
        for m in [MediaKind::ZNand, MediaKind::Pmem, MediaKind::Dram] {
            let mut c = cfg();
            let internal = c.ssd.internal_dram_bytes;
            c.ssd = SsdConfig::with_media(m);
            c.ssd.internal_dram_bytes = internal;
            c.prefetcher = PrefetcherKind::Expand;
            run(&c, WorkloadId::Tc, rt.as_ref());
        }
    });

    // --- End-to-end: runner_throughput group (tracked baseline) ---------
    let throughput = runner_throughput(&b);
    let ok_rt = publish_group(
        "runner_throughput",
        &throughput,
        opts.json_out.as_ref(),
        opts.check.as_ref(),
        "../BENCH_PR3.json",
        opts.max_regress,
        |_| {},
    );

    // --- End-to-end: multi_host_scaling group (tracked baseline) --------
    let (mh, speedup) = multi_host_scaling(&b);
    let ok_mh = publish_group(
        "multi_host_scaling",
        &mh,
        opts.mh_json_out.as_ref(),
        opts.mh_check.as_ref(),
        "../BENCH_PR4.json",
        opts.max_regress,
        |doc| {
            // The scaling headline rides as a top-level field so the
            // tracked file documents it next to the raw scenarios.
            if let (Json::Obj(m), Some(s)) = (doc, speedup) {
                m.insert(
                    "speedup_hosts4_threads4_vs_threads1".to_string(),
                    Json::Num((s * 100.0).round() / 100.0),
                );
                m.insert(
                    "measured_cores".to_string(),
                    Json::Num(expand_cxl::util::default_parallelism() as f64),
                );
            }
        },
    );

    // --- End-to-end: trace_replay group (tracked baseline) --------------
    let tr = trace_replay(&b);
    let ok_tr = publish_group(
        "trace_replay",
        &tr,
        opts.tr_json_out.as_ref(),
        opts.tr_check.as_ref(),
        "../BENCH_PR5.json",
        opts.max_regress,
        |_| {},
    );

    // --- End-to-end: batched_hot_loop group (tracked baseline) ----------
    let (b6, replay_ratio, obs_ratio, fault_ratio) = batched_hot_loop(&b);
    let ok_b6 = publish_group(
        "batched_hot_loop",
        &b6,
        opts.b6_json_out.as_ref(),
        opts.b6_check.as_ref(),
        "../BENCH_PR6.json",
        opts.max_regress,
        |doc| {
            // The zero-copy replay headline rides as a top-level field
            // (acceptance floor: >=1.5x over synthetic generation).
            if let Json::Obj(m) = doc {
                if let Some(r) = replay_ratio {
                    m.insert(
                        "replay_mmap_vs_synthetic_chain".to_string(),
                        Json::Num((r * 100.0).round() / 100.0),
                    );
                }
                if let Some(r) = obs_ratio {
                    m.insert(
                        "obs_overhead_on_vs_off".to_string(),
                        Json::Num((r * 100.0).round() / 100.0),
                    );
                }
                if let Some(r) = fault_ratio {
                    m.insert(
                        "fault_idle_vs_off".to_string(),
                        Json::Num((r * 100.0).round() / 100.0),
                    );
                }
            }
        },
    );
    // --- End-to-end: fleet_scaling group (tracked baseline) -------------
    let (fl, efficiency, profiler_ratio) = fleet_scaling(&b);
    let ok_fl = publish_group(
        "fleet_scaling",
        &fl,
        opts.fl_json_out.as_ref(),
        opts.fl_check.as_ref(),
        "../BENCH_PR9.json",
        opts.max_regress,
        |doc| {
            // The fleet headline: per-core scaling efficiency of the
            // 256-host hierarchical merge (acceptance floor 0.7), plus
            // the engine self-profiler's on/off throughput ratio
            // (target >=0.98, i.e. <=2% overhead).
            if let Json::Obj(m) = doc {
                if let Some(e) = efficiency {
                    m.insert(
                        "per_core_efficiency_hosts256".to_string(),
                        Json::Num((e * 100.0).round() / 100.0),
                    );
                }
                if let Some(r) = profiler_ratio {
                    m.insert(
                        "profiler_overhead_on_vs_off".to_string(),
                        Json::Num((r * 1000.0).round() / 1000.0),
                    );
                }
                m.insert(
                    "measured_cores".to_string(),
                    Json::Num(expand_cxl::util::default_parallelism() as f64),
                );
            }
        },
    );
    if !ok_rt || !ok_mh || !ok_tr || !ok_b6 || !ok_fl {
        std::process::exit(1);
    }

    // --- Micro: simulator core throughput (events/s) ---------------------
    if b.enabled("micro_sim_throughput_noprefetch") {
        let mut c = cfg();
        c.accesses = 200_000;
        let t0 = std::time::Instant::now();
        run(&c, WorkloadId::Pr, None);
        let dt = t0.elapsed().as_secs_f64();
        b.report("micro_sim_throughput_noprefetch", c.accesses as f64 / dt, "accesses/s");
    }

    // --- Micro: predictor inference latency ------------------------------
    if let Some(rt) = &rt {
        for model in ["expand", "ml1", "ml2"] {
            let name = format!("micro_inference_{model}");
            if !b.enabled(&name) {
                continue;
            }
            let p = rt.predictor(model).unwrap();
            let shape = p.borrow().shape();
            let win = WindowInput {
                deltas: vec![65; shape.window],
                pcs: vec![3; shape.window],
                hint: 0.0,
            };
            let t0 = std::time::Instant::now();
            let iters = 100;
            for _ in 0..iters {
                p.borrow_mut().predict(std::slice::from_ref(&win)).unwrap();
            }
            let per = t0.elapsed().as_secs_f64() / iters as f64;
            b.report(&name, per * 1e6, "us/prediction");
        }
    }

    println!(
        "\n{} benches + {} throughput scenarios completed",
        b.results.len(),
        throughput.len() + mh.len() + tr.len() + b6.len() + fl.len()
    );
}
