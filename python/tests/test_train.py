"""Training-loop smoke tests (short runs; full training happens at
`make artifacts`)."""

import numpy as np

import jax
import jax.numpy as jnp

from compile import config as C
from compile.model import init_expand_params
from compile.train import adam_init, adam_update, train_model


def test_adam_descends_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    opt = adam_init(params)
    for _ in range(400):
        grads = {"x": 2.0 * params["x"]}
        params, opt = adam_update(params, grads, opt, lr=0.1)
    assert np.abs(np.asarray(params["x"])).max() < 0.05


def test_short_training_reduces_loss_and_reports_metrics():
    _, metrics = train_model("expand", steps=40, batch=16, verbose=False)
    assert metrics["model"] == "expand"
    assert 0.0 <= metrics["eval_acc_top1"] <= 1.0
    assert metrics["steps"] == 40
    # Even 40 steps should beat uniform-random accuracy (1/128 ~ 0.8%).
    assert metrics["eval_acc_top1"] > 0.05


def test_training_is_seeded_deterministic():
    p1, m1 = train_model("ml1", steps=10, batch=8, verbose=False)
    p2, m2 = train_model("ml1", steps=10, batch=8, verbose=False)
    assert m1["eval_acc_top1"] == m2["eval_acc_top1"]
    a = jax.tree_util.tree_leaves(p1)[0]
    b = jax.tree_util.tree_leaves(p2)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
