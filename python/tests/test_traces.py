"""Trace-family generator tests: tokenization bounds, family structure,
and learnability (targets must be predictable from the window for the
deterministic families)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import traces
from compile.config import DELTA_VOCAB, PC_VOCAB


@settings(max_examples=50, deadline=None)
@given(delta=st.integers(-(10**9), 10**9))
def test_tokenize_delta_bounds(delta):
    tok = int(traces.tokenize_delta(delta))
    assert 0 <= tok < DELTA_VOCAB
    if abs(delta) > 63:
        assert tok == 0
    else:
        assert tok == delta + 64


@settings(max_examples=50, deadline=None)
@given(pc=st.integers(0, 2**63 - 1))
def test_hash_pc_bounds(pc):
    h = int(traces.hash_pc(pc))
    assert 0 <= h < PC_VOCAB


def test_hash_pc_reference_values():
    """Pinned values — rust/src/expand/tokenize.rs must match these."""
    # h = (pc * 0x9E3779B97F4A7C15) >> 56 mod 256
    for pc in [0x401000, 0x40_0100, 1, 2**40]:
        expect = ((pc * 0x9E3779B97F4A7C15) % 2**64) >> 56
        assert int(traces.hash_pc(pc)) == expect % 256


@pytest.mark.parametrize("family", traces.FAMILIES)
def test_families_produce_valid_windows(family):
    rng = np.random.default_rng(1)
    for _ in range(20):
        d, p, hint, tgt = traces.sample_window(rng, 32, 4, family=family)
        assert d.shape == (32,) and p.shape == (32,) and tgt.shape == (4,)
        assert d.dtype == np.int32
        assert (d >= 0).all() and (d < DELTA_VOCAB).all()
        assert (p >= 0).all() and (p < PC_VOCAB).all()
        assert hint == (1.0 if family == "phase_change" else 0.0)


def test_strided_family_is_constant():
    rng = np.random.default_rng(2)
    d, _, _, tgt = traces.sample_window(rng, 32, 4, family="strided")
    assert len(set(d.tolist())) == 1
    assert (tgt == d[0]).all(), "targets continue the stride"


def test_pointer_chase_is_periodic():
    rng = np.random.default_rng(3)
    d, _, _, tgt = traces.sample_window(rng, 32, 4, family="pointer_chase")
    # Find the period, then check targets continue it.
    full = np.concatenate([d, tgt])
    for period in range(4, 12):
        if all(full[i] == full[i % period] for i in range(len(full))):
            return
    pytest.fail("no period found in pointer_chase")


def test_batch_shapes():
    rng = np.random.default_rng(4)
    d, p, h, t = traces.sample_batch(rng, 16, 32, 4)
    assert d.shape == (16, 32)
    assert p.shape == (16, 32)
    assert h.shape == (16,)
    assert t.shape == (16, 4)
