"""L1 correctness: Pallas mm_attention vs the pure-jnp oracle.

This is the CORE kernel correctness signal: a hypothesis sweep over
shapes (window, head dim, batch*heads) and input distributions, plus
directed tests for the mask/bias semantics the model relies on.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels.mm_attention import mm_attention
from compile.kernels.ref import mm_attention_ref


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _causal_bias(bh, w, s):
    i = jnp.arange(w)[:, None]
    j = jnp.arange(w)[None, :]
    half = jnp.where(j <= i, 0.0, -1e9).astype(jnp.float32)
    reps = s // w
    return jnp.broadcast_to(
        jnp.concatenate([half] * reps, axis=-1)[None], (bh, w, s)
    )


@settings(max_examples=25, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 4, 8]),
    w=st.sampled_from([4, 8, 16, 32]),
    dh=st.sampled_from([8, 16, 32, 64]),
    smul=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_shape_sweep(bh, w, dh, smul, seed):
    s = w * smul
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (bh, w, dh), jnp.float32)
    k = jax.random.normal(ks[1], (bh, s, dh), jnp.float32)
    v = jax.random.normal(ks[2], (bh, s, dh), jnp.float32)
    bias = jax.random.normal(ks[3], (bh, w, s), jnp.float32)
    got = mm_attention(q, k, v, bias)
    want = mm_attention_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(0.01, 30.0), seed=st.integers(0, 2**31 - 1))
def test_matches_ref_input_scale(scale, seed):
    """Large-magnitude scores exercise the stable-softmax path."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = scale * jax.random.normal(ks[0], (2, 8, 16), jnp.float32)
    k = scale * jax.random.normal(ks[1], (2, 16, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 16, 16), jnp.float32)
    bias = jnp.zeros((2, 8, 16), jnp.float32)
    got = mm_attention(q, k, v, bias)
    want = mm_attention_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_rows_are_convex_combinations():
    """Attention output rows must lie in the convex hull of V rows: with
    constant V the output equals that constant regardless of scores."""
    bh, w, s, dh = 2, 8, 16, 8
    q = _rand(0, bh, w, dh)
    k = _rand(1, bh, s, dh)
    v = jnp.ones((bh, s, dh), jnp.float32) * 3.5
    bias = _rand(2, bh, w, s)
    out = mm_attention(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out), 3.5, rtol=1e-6)


def test_causal_mask_blocks_future():
    """With the model's causal bias, changing a future key/value row must
    not affect earlier query rows."""
    bh, w, dh = 2, 8, 16
    s = 2 * w
    q = _rand(3, bh, w, dh)
    k = _rand(4, bh, s, dh)
    v = _rand(5, bh, s, dh)
    bias = _causal_bias(bh, w, s)
    base = np.asarray(mm_attention(q, k, v, bias))
    # Perturb the *last* position of both modality halves.
    k2 = k.at[:, w - 1].add(100.0).at[:, s - 1].add(100.0)
    v2 = v.at[:, w - 1].add(100.0).at[:, s - 1].add(100.0)
    pert = np.asarray(mm_attention(q, k2, v2, bias))
    np.testing.assert_allclose(pert[:, : w - 1], base[:, : w - 1],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(pert[:, w - 1], base[:, w - 1])


def test_bias_shifts_attention():
    """A strong positive bias toward one key makes the output approach
    that key's value row."""
    bh, w, s, dh = 1, 4, 8, 8
    q = _rand(6, bh, w, dh)
    k = _rand(7, bh, s, dh)
    v = _rand(8, bh, s, dh)
    bias = jnp.zeros((bh, w, s), jnp.float32).at[:, :, 3].set(1e4)
    out = np.asarray(mm_attention(q, k, v, bias))
    target = np.asarray(v)[:, 3]
    for i in range(w):
        np.testing.assert_allclose(out[:, i], target, rtol=1e-3, atol=1e-3)


def test_jit_and_grad_through_kernel():
    """The kernel must be differentiable (online-refinement path) and
    jit-composable inside a larger graph."""
    bh, w, s, dh = 2, 4, 8, 8
    q = _rand(9, bh, w, dh)
    k = _rand(10, bh, s, dh)
    v = _rand(11, bh, s, dh)
    bias = jnp.zeros((bh, w, s), jnp.float32)

    def loss(q):
        return jnp.sum(mm_attention(q, k, v, bias) ** 2)

    def loss_ref(q):
        return jnp.sum(mm_attention_ref(q, k, v, bias) ** 2)

    g = jax.grad(loss)(q)
    g_ref = jax.grad(loss_ref)(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)
