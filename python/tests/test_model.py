"""L2 model tests: shapes, determinism, pallas/ref path equality, the
behavior-hint path, and parameter-footprint sanity vs Table 1d."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import config as C
from compile.model import MODELS, init_expand_params, expand_fwd, param_bytes

CFG = C.ModelConfig()


def _inputs(seed=0, batch=CFG.batch, hint=0.0):
    rng = np.random.default_rng(seed)
    deltas = rng.integers(0, CFG.delta_vocab, (batch, CFG.window)).astype(np.int32)
    pcs = rng.integers(0, CFG.pc_vocab, (batch, CFG.window)).astype(np.int32)
    h = np.full((batch,), hint, np.float32)
    return deltas, pcs, h


@pytest.mark.parametrize("name", sorted(MODELS))
def test_output_shape_and_finiteness(name):
    init, fwd = MODELS[name]
    params = init(jax.random.PRNGKey(1), CFG)
    d, p, h = _inputs()
    logits = np.asarray(fwd(params, CFG, d, p, h, use_pallas=False))
    assert logits.shape == (CFG.batch, CFG.n_future, CFG.delta_vocab)
    assert np.isfinite(logits).all()


@pytest.mark.parametrize("name", sorted(MODELS))
def test_deterministic(name):
    init, fwd = MODELS[name]
    params = init(jax.random.PRNGKey(2), CFG)
    d, p, h = _inputs(3)
    a = np.asarray(fwd(params, CFG, d, p, h, use_pallas=False))
    b = np.asarray(fwd(params, CFG, d, p, h, use_pallas=False))
    np.testing.assert_array_equal(a, b)


def test_pallas_and_ref_paths_agree():
    """The exported (pallas) graph must match the training (ref) graph."""
    params = init_expand_params(jax.random.PRNGKey(4), CFG)
    d, p, h = _inputs(5, hint=0.7)
    ref = np.asarray(expand_fwd(params, CFG, d, p, h, use_pallas=False))
    pal = np.asarray(expand_fwd(params, CFG, d, p, h, use_pallas=True))
    np.testing.assert_allclose(pal, ref, rtol=2e-4, atol=2e-4)


def test_hint_changes_expand_output():
    """The behavior-change hint gates the recency bias — it must actually
    alter the prediction distribution (the online-tuning mechanism)."""
    params = init_expand_params(jax.random.PRNGKey(6), CFG)
    d, p, _ = _inputs(7)
    h0 = np.zeros((CFG.batch,), np.float32)
    h1 = np.ones((CFG.batch,), np.float32)
    a = np.asarray(expand_fwd(params, CFG, d, p, h0, use_pallas=False))
    b = np.asarray(expand_fwd(params, CFG, d, p, h1, use_pallas=False))
    assert not np.allclose(a, b), "hint must influence logits"


def test_hint_is_ignored_by_baselines():
    for name in ["ml1", "ml2"]:
        init, fwd = MODELS[name]
        params = init(jax.random.PRNGKey(8), CFG)
        d, p, _ = _inputs(9)
        h0 = np.zeros((CFG.batch,), np.float32)
        h1 = np.ones((CFG.batch,), np.float32)
        a = np.asarray(fwd(params, CFG, d, p, h0, use_pallas=False))
        b = np.asarray(fwd(params, CFG, d, p, h1, use_pallas=False))
        np.testing.assert_array_equal(a, b)


def test_param_footprint_is_sub_2mb():
    """Table 1d reports ~839 KB-class overheads for ML prefetchers; our
    configs land in the same sub-2 MB class (documented in DESIGN.md)."""
    for name in sorted(MODELS):
        init, _ = MODELS[name]
        params = init(jax.random.PRNGKey(10), CFG)
        b = param_bytes(params)
        assert 200_000 < b < 2_000_000, f"{name}: {b} bytes"


def test_variable_batch_sizes_trace():
    params = init_expand_params(jax.random.PRNGKey(11), CFG)
    for batch in [1, 2, 8]:
        d, p, h = _inputs(12, batch=batch)
        out = expand_fwd(params, CFG, d, p, h, use_pallas=False)
        assert out.shape == (batch, CFG.n_future, CFG.delta_vocab)
