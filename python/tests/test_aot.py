"""AOT export tests: HLO text integrity (no elided constants!), entry
signature, and probe self-consistency."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import config as C
from compile.aot import lower_model, probe_model
from compile.model import MODELS


@pytest.fixture(scope="module")
def tiny_expand():
    cfg = C.EXPORT
    init, _ = MODELS["expand"]
    params = init(jax.random.PRNGKey(0), cfg)
    return params, cfg


def test_hlo_text_has_full_constants(tiny_expand):
    """Regression: the default printer elides big constants as `{...}`,
    which the Rust-side text parser reads back as zeros."""
    params, cfg = tiny_expand
    hlo = lower_model("expand", params, cfg)
    assert "ENTRY" in hlo
    assert "constant({...})" not in hlo, "weights were elided from the HLO text"
    # Embeddings are 128x128 floats: the text must be megabytes, not KB.
    assert len(hlo) > 1_000_000


def test_entry_signature_matches_contract(tiny_expand):
    params, cfg = tiny_expand
    hlo = lower_model("expand", params, cfg)
    b, w = cfg.batch, cfg.window
    assert f"s32[{b},{w}]" in hlo, "delta/pc token parameters"
    assert f"f32[{b}]" in hlo, "hint parameter"
    assert f"(f32[{b},{cfg.n_future},{cfg.delta_vocab}]" in hlo, "tuple(logits) root"


def test_probe_matches_direct_forward(tiny_expand):
    params, cfg = tiny_expand
    probes = probe_model("expand", params, cfg)
    _, fwd = MODELS["expand"]
    for label, probe in probes.items():
        deltas = np.full((cfg.batch, cfg.window), probe["delta_token"], np.int32)
        pcs = np.full((cfg.batch, cfg.window), probe["pc_token"], np.int32)
        hint = np.zeros((cfg.batch,), np.float32)
        logits = fwd(params, cfg, deltas, pcs, hint, use_pallas=True)
        toks = np.argmax(np.asarray(logits)[0], axis=-1).tolist()
        assert toks == probe["expect_tokens"], label
