"""Synthetic trace families for pretraining the address predictors.

The paper pretrains the decider's models offline and refines them online.
We pretrain on *pattern families* rather than concrete workload traces so
the models generalize to the (structurally similar, but independently
generated) traces the Rust workload generators emit at simulation time —
the accuracy the Rust harness measures is therefore genuine, not leakage.

Families mirror the access signatures the evaluation workloads exhibit:
  strided       — unit/constant-stride streaming (libquantum, PR edge scans)
  multi_stride  — loop nests cycling 2..4 strides, each tied to its own PC
  stencil       — periodic neighbor-offset patterns (bwaves/leslie3d/lbm)
  graph_csr     — CSR neighbor-scan bursts (+1 runs) punctuated by jumps
                  (CC/PR/SSSP frontier expansion)
  pointer_chase — repeating delta cycles, single PC (mcf, temporal reuse)
  phase_change  — a boundary between two families inside the window, with
                  hint=1 (trains the behavior-hint gating path)

Tokenization contract is shared with rust/src/expand/tokenize.rs via
config.py: delta tokens = clamp(line_delta, ±63) + 64, 0 = OOV.
"""

import numpy as np

from .config import DELTA_CLAMP, DELTA_VOCAB, PC_VOCAB

FAMILIES = (
    "strided",
    "multi_stride",
    "stencil",
    "graph_csr",
    "pointer_chase",
    "phase_change",
)


def tokenize_delta(delta):
    """Map a line-granularity address delta to its vocab token."""
    d = np.asarray(delta)
    tok = np.clip(d, -DELTA_CLAMP, DELTA_CLAMP) + (DELTA_VOCAB // 2)
    tok = np.where(np.abs(d) > DELTA_CLAMP, 0, tok)
    return tok.astype(np.int32)


def hash_pc(pc):
    """Multiplicative PC hash into PC_VOCAB buckets (matches tokenize.rs)."""
    pc = np.asarray(pc, dtype=np.uint64)
    h = (pc * np.uint64(0x9E3779B97F4A7C15)) >> np.uint64(64 - 8)
    return (h % np.uint64(PC_VOCAB)).astype(np.int32)


# --- family generators -----------------------------------------------------
# Each returns (deltas i64[n], pcs u64[n]) for n = window + k_future.


def _gen_strided(rng, n):
    s = int(rng.integers(1, 9)) * int(rng.choice([-1, 1]))
    pc = int(rng.integers(1 << 20, 1 << 40))
    return np.full(n, s, dtype=np.int64), np.full(n, pc, dtype=np.uint64)


def _gen_multi_stride(rng, n):
    k = int(rng.integers(2, 5))
    strides = rng.integers(-16, 17, size=k)
    strides[strides == 0] = 1
    pcs = rng.integers(1 << 20, 1 << 40, size=k).astype(np.uint64)
    idx = np.arange(n) % k
    return strides[idx].astype(np.int64), pcs[idx]


def _gen_stencil(rng, n):
    # Periodic neighbor-offset pattern, e.g. [1, 1, L-2, 1, 1, L-2, ...]
    period = int(rng.integers(3, 8))
    pat = rng.integers(-40, 41, size=period)
    pat[pat == 0] = 1
    pc = int(rng.integers(1 << 20, 1 << 40))
    idx = np.arange(n) % period
    return pat[idx].astype(np.int64), np.full(n, pc, dtype=np.uint64)


def _gen_graph_csr(rng, n):
    # Bursts of +1 (neighbor-list scan) of geometric length, separated by
    # large jumps (next frontier vertex). Scan and jump use distinct PCs.
    deltas = np.empty(n, dtype=np.int64)
    pcs = np.empty(n, dtype=np.uint64)
    scan_pc = int(rng.integers(1 << 20, 1 << 40))
    jump_pc = int(rng.integers(1 << 20, 1 << 40))
    i = 0
    while i < n:
        burst = int(rng.geometric(0.25))
        for _ in range(min(burst, n - i)):
            deltas[i] = 1
            pcs[i] = scan_pc
            i += 1
        if i < n:
            deltas[i] = int(rng.integers(100, 100000)) * int(rng.choice([-1, 1]))
            pcs[i] = jump_pc
            i += 1
    return deltas, pcs


def _gen_pointer_chase(rng, n):
    # A repeating cycle of irregular deltas — pure temporal correlation.
    period = int(rng.integers(4, 12))
    cyc = rng.integers(-DELTA_CLAMP, DELTA_CLAMP + 1, size=period)
    cyc[cyc == 0] = 3
    pc = int(rng.integers(1 << 20, 1 << 40))
    idx = np.arange(n) % period
    return cyc[idx].astype(np.int64), np.full(n, pc, dtype=np.uint64)


_BASE = {
    "strided": _gen_strided,
    "multi_stride": _gen_multi_stride,
    "stencil": _gen_stencil,
    "graph_csr": _gen_graph_csr,
    "pointer_chase": _gen_pointer_chase,
}


def _gen_phase_change(rng, n):
    a, b = rng.choice(list(_BASE), size=2, replace=False)
    cut = int(rng.integers(n // 4, 3 * n // 4))
    da, pa = _BASE[a](rng, n)
    db, pb = _BASE[b](rng, n)
    return (
        np.concatenate([da[:cut], db[cut:]]),
        np.concatenate([pa[:cut], pb[cut:]]),
    )


def sample_window(rng, window, k_future, family=None):
    """One training sample: (deltas [W], pcs [W], hint, targets [K])."""
    fam = family or rng.choice(FAMILIES)
    n = window + k_future
    if fam == "phase_change":
        d, p = _gen_phase_change(rng, n)
        hint = 1.0
    else:
        d, p = _BASE[fam](rng, n)
        hint = 0.0
    toks = tokenize_delta(d)
    pcs = hash_pc(p)
    return toks[:window], pcs[:window], np.float32(hint), toks[window:]


def sample_batch(rng, batch, window, k_future):
    """Batched sampler -> (deltas [B,W], pcs [B,W], hint [B], tgt [B,K])."""
    ds, ps, hs, ts = [], [], [], []
    for _ in range(batch):
        d, p, h, t = sample_window(rng, window, k_future)
        ds.append(d)
        ps.append(p)
        hs.append(h)
        ts.append(t)
    return (
        np.stack(ds).astype(np.int32),
        np.stack(ps).astype(np.int32),
        np.asarray(hs, np.float32),
        np.stack(ts).astype(np.int32),
    )
