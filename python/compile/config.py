"""Shared model/tokenizer configuration for the ExPAND predictor stack.

These constants mirror Table 1b of the paper (attention dim 64, modality
fusion dim 128, transformer dim 128) and define the interchange contract
with the Rust runtime (see ``rust/src/runtime/``): window length, vocab
sizes, batch, and prefetch degree are baked into the exported HLO shapes
and re-read by Rust from ``artifacts/manifest.json``.
"""

from dataclasses import dataclass, asdict

# --- Tokenizer contract (must match rust/src/expand/tokenize.rs) ---------
# Address deltas are measured in 64B cache lines between successive LLC
# misses, clamped to [-63, +63] and offset by +64 -> tokens 1..127.
# Token 0 is out-of-vocabulary (jump larger than +-63 lines).
DELTA_VOCAB = 128
DELTA_CLAMP = 63
# PCs are hashed into 256 buckets (multiplicative hash, see tokenize.rs).
PC_VOCAB = 256


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (Table 1b)."""

    window: int = 32          # sliding window of recent LLC misses
    d_model: int = 128        # transformer dim
    d_head: int = 64          # attention dim
    n_heads: int = 2          # d_head * n_heads == d_model
    n_layers: int = 2
    d_fusion: int = 128       # modality fusion MLP hidden dim
    n_future: int = 4         # prefetch degree: predict next-K deltas
    batch: int = 4            # decider batch size (fixed in HLO)
    delta_vocab: int = DELTA_VOCAB
    pc_vocab: int = PC_VOCAB
    recency_beta: float = 0.25  # hint-gated recency bias slope

    def asdict(self):
        return asdict(self)


# Default export configuration; the Rust side reads these from the
# manifest, so changing them here is sufficient to re-shape the stack.
EXPORT = ModelConfig()

# Training hyper-parameters used by train.py at `make artifacts` time.
TRAIN_STEPS = 1500
TRAIN_BATCH = 64
LEARNING_RATE = 2e-3
EVAL_BATCHES = 8
SEED = 20260710
