"""AOT export: train the predictors and lower them to HLO text artifacts.

This is the single build-time entry point (``make artifacts``). Python
never runs on the request path — the Rust coordinator loads the emitted
``artifacts/*.hlo.txt`` through the PJRT CPU client (rust/src/runtime/).

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (per model: expand, ml1, ml2):
  artifacts/<name>.hlo.txt   lowered fwd pass, trained weights as constants
  artifacts/manifest.json    shapes/vocab contract + training metrics that
                             the Rust runtime and Table-1d harness consume
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import config as C
from .model import MODELS, make_forward, param_bytes
from .train import train_model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe route).

    ``print_large_constants=True`` is load-bearing: the default HLO
    printer elides big constants as ``{...}``, which the text parser on
    the Rust side silently reads back as *zeros* — turning the baked-in
    trained weights into an all-zero model.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(name, params, cfg):
    """Bind trained params and lower the fwd pass for fixed export shapes.

    The baselines ignore ``hint``; without the `h * 0` anchor jax would
    drop the unused parameter from the lowered module and the Rust
    runtime (which always feeds three buffers) would fail with a buffer-
    count mismatch. The anchor keeps the entry signature uniform across
    all three models.
    """
    fwd = make_forward(name, params, cfg, use_pallas=True)

    def entry(deltas, pcs, hint):
        logits = fwd(deltas, pcs, hint)
        return logits + (hint * 0.0)[:, None, None]

    d_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.window), jnp.int32)
    p_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.window), jnp.int32)
    h_spec = jax.ShapeDtypeStruct((cfg.batch,), jnp.float32)
    lowered = jax.jit(entry).lower(d_spec, p_spec, h_spec)
    return to_hlo_text(lowered)


def probe_model(name, params, cfg):
    """Canned-input predictions recorded in the manifest; the Rust
    runtime-roundtrip test replays them to pin artifact integrity
    (catching e.g. elided-constant or layout regressions)."""
    _, fwd = MODELS[name]
    probes = {}
    for label, delta_tok in [("stride3", 67), ("stride1", 65)]:
        deltas = np.full((cfg.batch, cfg.window), delta_tok, np.int32)
        pcs = np.full((cfg.batch, cfg.window), 42, np.int32)
        hint = np.zeros((cfg.batch,), np.float32)
        logits = fwd(params, cfg, deltas, pcs, hint, use_pallas=True)
        toks = np.argmax(np.asarray(logits)[0], axis=-1)
        probes[label] = {"delta_token": delta_tok, "pc_token": 42,
                         "expect_tokens": [int(t) for t in toks]}
    return probes


def train_cached(name, cfg, steps, out_dir):
    """Train with an on-disk cache (build-time convenience: re-lowering
    after an aot.py change must not cost a retrain). Cache key = model,
    steps, seed, and config shape."""
    import pickle

    key = f"{name}-s{steps}-seed{C.SEED}-w{cfg.window}d{cfg.d_model}"
    cache = os.path.join(out_dir, f".params_{key}.pkl")
    if os.path.exists(cache):
        with open(cache, "rb") as f:
            params, metrics = pickle.load(f)
        print(f"[aot] loaded cached params for {name} ({cache})")
        return params, metrics
    params, metrics = train_model(name, cfg, steps=steps)
    with open(cache, "wb") as f:
        pickle.dump((jax.device_get(params), metrics), f)
    return params, metrics


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--steps", type=int, default=C.TRAIN_STEPS)
    ap.add_argument("--models", default="expand,ml1,ml2")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training run (CI/test path)")
    args = ap.parse_args()

    steps = 30 if args.quick else args.steps
    os.makedirs(args.out, exist_ok=True)
    cfg = C.EXPORT

    manifest = {
        "config": cfg.asdict(),
        "format": "hlo-text",
        "models": {},
    }
    for name in args.models.split(","):
        name = name.strip()
        if name not in MODELS:
            raise SystemExit(f"unknown model {name!r}; have {sorted(MODELS)}")
        params, metrics = train_cached(name, cfg, steps, args.out)
        hlo = lower_model(name, params, cfg)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        manifest["models"][name] = {
            "file": f"{name}.hlo.txt",
            "param_bytes": param_bytes(params),
            "hlo_chars": len(hlo),
            "probes": probe_model(name, params, cfg),
            **metrics,
        }
        print(f"[aot] wrote {path} ({len(hlo)} chars, "
              f"{param_bytes(params)} param bytes)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {mpath}")


if __name__ == "__main__":
    main()
