"""Pure-jnp correctness oracle for the Pallas multi-modality attention.

Used two ways:
  * pytest (python/tests/test_kernel.py) asserts the Pallas kernel matches
    this reference across a hypothesis sweep of shapes and inputs — the
    core L1 correctness signal;
  * train.py uses the reference on the training path (interpret-mode
    Pallas is slow under autodiff); aot.py exports with the Pallas kernel
    so the shipped HLO exercises the fused form. test_model.py asserts the
    two paths produce identical logits.
"""

import jax
import jax.numpy as jnp


@jax.jit
def mm_attention_ref(q, k, v, bias):
    """Reference multi-modality attention.

    Same contract as kernels.mm_attention.mm_attention:
      q f32[BH, W, Dh], k/v f32[BH, S, Dh], bias f32[BH, W, S].
    """
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bwd,bsd->bws", q, k) * scale + bias
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bws,bsd->bwd", p, v)
