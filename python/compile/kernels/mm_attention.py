"""L1 Pallas kernel: fused multi-modality attention.

This is the compute hot-spot of ExPAND's address predictor (paper §
"Prefetch Address and Timing Speculation"): queries come from the address
(delta) stream, keys/values from the concatenation of the address and PC
modality streams, and a per-window additive bias carries both the causal
mask and the *behavior-hint-gated recency bias* (the decision-tree
classifier's phase-change signal re-weights attention toward recent
history — the paper's online-tuning mechanism).

The whole QK^T -> softmax -> PV chain is fused in one kernel so the
(W x S) score matrix never leaves VMEM. TPU adaptation notes are in
DESIGN.md §Hardware-Adaptation: per-grid-step VMEM footprint is
(W + 2S)·Dh·4B + W·S·4B ≈ 29 KB at W=32, S=64, Dh=64 — latency-bound, not
capacity-bound, with MXU-friendly (W x Dh)·(Dh x S) matmul shapes.

``interpret=True`` is mandatory on this image: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute; interpret mode
lowers to plain HLO ops that round-trip through the Rust loader.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import mm_attention_ref


def _mm_attention_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale):
    """One (batch*head) slice: q [W,Dh], k/v [S,Dh], bias [W,S]."""
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    b = bias_ref[0]
    # Scores with mask + hint-recency folded into the additive bias.
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale + b
    # Numerically-stable softmax, fully in registers/VMEM.
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def _mm_attention_impl(q, k, v, bias, interpret=True):
    """Pallas forward implementation (see mm_attention for the contract)."""
    bh, w, dh = q.shape
    s = k.shape[1]
    scale = 1.0 / (dh ** 0.5)
    kern = functools.partial(_mm_attention_kernel, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, w, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, w, s), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, w, dh), jnp.float32),
        interpret=interpret,
    )(q, k, v, bias)


@jax.custom_vjp
def mm_attention(q, k, v, bias):
    """Fused multi-modality attention.

    Args:
      q:    f32[BH, W, Dh]  queries (address-stream modality).
      k:    f32[BH, S, Dh]  keys over concatenated modalities (S = 2W).
      v:    f32[BH, S, Dh]  values over concatenated modalities.
      bias: f32[BH, W, S]   additive bias = causal mask + hint * recency.

    Returns:
      f32[BH, W, Dh] attention output.

    Forward runs the fused Pallas kernel (interpret mode — see module
    docstring); the backward pass is defined via the jnp reference because
    interpret-mode Pallas does not support reverse-mode autodiff in this
    jax version. The online-refinement path only differentiates at build
    time, so this costs nothing on the request path.
    """
    return _mm_attention_impl(q, k, v, bias)


def _vjp_fwd(q, k, v, bias):
    return _mm_attention_impl(q, k, v, bias), (q, k, v, bias)


def _vjp_bwd(residuals, g):
    _, vjp = jax.vjp(mm_attention_ref, *residuals)
    return vjp(g)


mm_attention.defvjp(_vjp_fwd, _vjp_bwd)
