"""L2: the address-predictor models, in JAX, calling the L1 Pallas kernel.

Three models share one I/O contract so the Rust runtime drives them through
a single typed interface (rust/src/runtime/predictor.rs):

    inputs : deltas i32[B, W], pcs i32[B, W], hint f32[B]
    output : logits f32[B, K, DELTA_VOCAB]   (K = prefetch degree)

* ``expand``  — the paper's heterogeneous predictor: a multi-modality
  transformer whose attention layer is the fused Pallas kernel
  (kernels/mm_attention.py). The behavior-change *hint* from the decision
  tree classifier gates an additive recency bias so the model re-weights
  recent history after a phase change (the paper's online-tuning path).
* ``ml1``     — LSTM baseline (hierarchical-neural-prefetcher class [39]).
* ``ml2``     — plain causal transformer baseline (TransFetch class [32]);
  no modality fusion, no hint.

Parameters are *closed over* at export time so they lower into HLO
constants — the Rust side never sees weights, only activations.
"""

import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels.mm_attention import mm_attention
from .kernels.ref import mm_attention_ref

# --------------------------------------------------------------------------
# Small building blocks
# --------------------------------------------------------------------------


def _dense_init(key, n_in, n_out):
    scale = (2.0 / (n_in + n_out)) ** 0.5
    return scale * jax.random.normal(key, (n_in, n_out), jnp.float32)


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _ln_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


# --------------------------------------------------------------------------
# ExPAND multi-modality transformer
# --------------------------------------------------------------------------


def init_expand_params(key, cfg: ModelConfig):
    """Initialize the ExPAND predictor parameter tree."""
    ks = iter(jax.random.split(key, 64))
    d, dh, nh = cfg.d_model, cfg.d_head, cfg.n_heads
    assert dh * nh == d, "n_heads * d_head must equal d_model"
    p = {
        "delta_emb": 0.02 * jax.random.normal(next(ks), (cfg.delta_vocab, d)),
        "pc_emb": 0.02 * jax.random.normal(next(ks), (cfg.pc_vocab, d)),
        "pos_a": 0.02 * jax.random.normal(next(ks), (cfg.window, d)),
        "pos_p": 0.02 * jax.random.normal(next(ks), (cfg.window, d)),
        "ln_f": _ln_init(d),
        "layers": [],
        # K small per-offset heads + a tied projection into delta vocab.
        "head_proj": [_dense_init(next(ks), d, d) for _ in range(cfg.n_future)],
        "head_bias": [jnp.zeros((cfg.delta_vocab,), jnp.float32) for _ in range(cfg.n_future)],
    }
    for _ in range(cfg.n_layers):
        lp = {
            "ln_a": _ln_init(d),
            "ln_p": _ln_init(d),
            "ln_m": _ln_init(d),
            "wq": _dense_init(next(ks), d, nh * dh),
            "wk": _dense_init(next(ks), d, nh * dh),
            "wv": _dense_init(next(ks), d, nh * dh),
            "wo": _dense_init(next(ks), nh * dh, d),
            "w1": _dense_init(next(ks), d, cfg.d_fusion),
            "w2": _dense_init(next(ks), cfg.d_fusion, d),
        }
        p["layers"].append(lp)
    return p


def _attention_bias(cfg: ModelConfig, hint, n_heads):
    """Additive bias [B, H, W, 2W]: causal mask over both modality halves
    plus a hint-gated recency slope (the online-tuning mechanism)."""
    w = cfg.window
    i = jnp.arange(w)[:, None]
    j = jnp.arange(w)[None, :]
    causal = jnp.where(j <= i, 0.0, -1e9).astype(jnp.float32)  # [W, W]
    # Same causal structure for the addr half and the pc half.
    mask = jnp.concatenate([causal, causal], axis=-1)  # [W, 2W]
    # Recency: prefer recent key positions; gated by the behavior hint.
    rec_half = (-cfg.recency_beta * (i - j)).astype(jnp.float32)  # <=0 for j<=i
    rec = jnp.concatenate([rec_half, rec_half], axis=-1)  # [W, 2W]
    bias = mask[None, None] + hint[:, None, None, None] * rec[None, None]
    return jnp.broadcast_to(bias, (hint.shape[0], n_heads, w, 2 * w))


def expand_fwd(params, cfg: ModelConfig, deltas, pcs, hint, use_pallas=True):
    """ExPAND predictor forward pass.

    Args:
      deltas: i32[B, W] delta tokens (newest last).
      pcs:    i32[B, W] hashed PC tokens.
      hint:   f32[B] behavior-change hint in [0, 1].
      use_pallas: route attention through the fused Pallas kernel (export
        path) or the jnp reference (training path; numerically identical).
    Returns:
      logits f32[B, K, delta_vocab].
    """
    b, w = deltas.shape
    d, dh, nh = cfg.d_model, cfg.d_head, cfg.n_heads
    attn_fn = mm_attention if use_pallas else mm_attention_ref

    x = params["delta_emb"][deltas] + params["pos_a"][None]  # [B, W, D]
    pe = params["pc_emb"][pcs] + params["pos_p"][None]       # [B, W, D]
    bias = _attention_bias(cfg, hint, nh)                    # [B, H, W, 2W]
    bias_f = bias.reshape(b * nh, w, 2 * w)

    for lp in params["layers"]:
        xn = layer_norm(x, lp["ln_a"]["g"], lp["ln_a"]["b"])
        pn = layer_norm(pe, lp["ln_p"]["g"], lp["ln_p"]["b"])
        ctx = jnp.concatenate([xn, pn], axis=1)              # [B, 2W, D]

        def split_heads(t, length):
            return (
                t.reshape(b, length, nh, dh)
                .transpose(0, 2, 1, 3)
                .reshape(b * nh, length, dh)
            )

        q = split_heads(xn @ lp["wq"], w)
        k = split_heads(ctx @ lp["wk"], 2 * w)
        v = split_heads(ctx @ lp["wv"], 2 * w)
        o = attn_fn(q, k, v, bias_f)                         # [B*H, W, Dh]
        o = (
            o.reshape(b, nh, w, dh)
            .transpose(0, 2, 1, 3)
            .reshape(b, w, nh * dh)
        )
        x = x + o @ lp["wo"]
        xm = layer_norm(x, lp["ln_m"]["g"], lp["ln_m"]["b"])
        x = x + jax.nn.gelu(xm @ lp["w1"]) @ lp["w2"]

    f = layer_norm(x[:, -1], params["ln_f"]["g"], params["ln_f"]["b"])  # [B, D]
    # Tied output embedding: per-offset projection then delta_emb^T.
    logits = [
        (f @ hp) @ params["delta_emb"].T + hb
        for hp, hb in zip(params["head_proj"], params["head_bias"])
    ]
    return jnp.stack(logits, axis=1)  # [B, K, V]


# --------------------------------------------------------------------------
# ML1: LSTM baseline
# --------------------------------------------------------------------------


def init_ml1_params(key, cfg: ModelConfig):
    ks = iter(jax.random.split(key, 16))
    d = cfg.d_model
    return {
        "delta_emb": 0.02 * jax.random.normal(next(ks), (cfg.delta_vocab, d)),
        "pc_emb": 0.02 * jax.random.normal(next(ks), (cfg.pc_vocab, d)),
        "wx": _dense_init(next(ks), d, 4 * d),
        "wh": _dense_init(next(ks), d, 4 * d),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "ln_f": _ln_init(d),
        "head_proj": [_dense_init(next(ks), d, d) for _ in range(cfg.n_future)],
        "head_bias": [jnp.zeros((cfg.delta_vocab,), jnp.float32) for _ in range(cfg.n_future)],
    }


def ml1_fwd(params, cfg: ModelConfig, deltas, pcs, hint, use_pallas=True):
    """LSTM baseline: embeds delta+pc sums, scans an LSTM, K heads.

    ``hint``/``use_pallas`` are accepted for interface uniformity; the
    baseline ignores them (it has no phase-change path and no kernel).
    """
    del hint, use_pallas
    b, w = deltas.shape
    d = cfg.d_model
    x = params["delta_emb"][deltas] + params["pc_emb"][pcs]  # [B, W, D]
    xt = x.transpose(1, 0, 2)  # [W, B, D] for scan

    def step(carry, xin):
        h, c = carry
        z = xin @ params["wx"] + h @ params["wh"] + params["bias"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    h0 = jnp.zeros((b, d), jnp.float32)
    (h, _), _ = jax.lax.scan(step, (h0, h0), xt)
    f = layer_norm(h, params["ln_f"]["g"], params["ln_f"]["b"])
    logits = [
        (f @ hp) @ params["delta_emb"].T + hb
        for hp, hb in zip(params["head_proj"], params["head_bias"])
    ]
    return jnp.stack(logits, axis=1)


# --------------------------------------------------------------------------
# ML2: plain causal transformer baseline
# --------------------------------------------------------------------------


def init_ml2_params(key, cfg: ModelConfig):
    ks = iter(jax.random.split(key, 64))
    d, dh, nh = cfg.d_model, cfg.d_head, cfg.n_heads
    p = {
        "delta_emb": 0.02 * jax.random.normal(next(ks), (cfg.delta_vocab, d)),
        "pc_emb": 0.02 * jax.random.normal(next(ks), (cfg.pc_vocab, d)),
        "pos": 0.02 * jax.random.normal(next(ks), (cfg.window, d)),
        "ln_f": _ln_init(d),
        "layers": [],
        "head_proj": [_dense_init(next(ks), d, d) for _ in range(cfg.n_future)],
        "head_bias": [jnp.zeros((cfg.delta_vocab,), jnp.float32) for _ in range(cfg.n_future)],
    }
    for _ in range(cfg.n_layers):
        p["layers"].append({
            "ln_1": _ln_init(d),
            "ln_2": _ln_init(d),
            "wq": _dense_init(next(ks), d, nh * dh),
            "wk": _dense_init(next(ks), d, nh * dh),
            "wv": _dense_init(next(ks), d, nh * dh),
            "wo": _dense_init(next(ks), nh * dh, d),
            "w1": _dense_init(next(ks), d, cfg.d_fusion),
            "w2": _dense_init(next(ks), cfg.d_fusion, d),
        })
    return p


def ml2_fwd(params, cfg: ModelConfig, deltas, pcs, hint, use_pallas=True):
    """TransFetch-class baseline: single-stream causal self-attention over
    (delta + pc) token embeddings. No modality fusion, no hint gating."""
    del hint, use_pallas
    b, w = deltas.shape
    d, dh, nh = cfg.d_model, cfg.d_head, cfg.n_heads
    x = params["delta_emb"][deltas] + params["pc_emb"][pcs] + params["pos"][None]

    i = jnp.arange(w)[:, None]
    j = jnp.arange(w)[None, :]
    causal = jnp.where(j <= i, 0.0, -1e9).astype(jnp.float32)

    for lp in params["layers"]:
        xn = layer_norm(x, lp["ln_1"]["g"], lp["ln_1"]["b"])

        def split_heads(t):
            return t.reshape(b, w, nh, dh).transpose(0, 2, 1, 3)

        q = split_heads(xn @ lp["wq"])
        k = split_heads(xn @ lp["wk"])
        v = split_heads(xn @ lp["wv"])
        s = jnp.einsum("bhwd,bhsd->bhws", q, k) / (dh ** 0.5) + causal
        o = jnp.einsum("bhws,bhsd->bhwd", jax.nn.softmax(s, axis=-1), v)
        o = o.transpose(0, 2, 1, 3).reshape(b, w, nh * dh)
        x = x + o @ lp["wo"]
        xm = layer_norm(x, lp["ln_2"]["g"], lp["ln_2"]["b"])
        x = x + jax.nn.gelu(xm @ lp["w1"]) @ lp["w2"]

    f = layer_norm(x[:, -1], params["ln_f"]["g"], params["ln_f"]["b"])
    logits = [
        (f @ hp) @ params["delta_emb"].T + hb
        for hp, hb in zip(params["head_proj"], params["head_bias"])
    ]
    return jnp.stack(logits, axis=1)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

MODELS = {
    "expand": (init_expand_params, expand_fwd),
    "ml1": (init_ml1_params, ml1_fwd),
    "ml2": (init_ml2_params, ml2_fwd),
}


def param_bytes(params):
    """Total parameter storage in bytes (Table 1d 'Memory overhead')."""
    leaves = jax.tree_util.tree_leaves(params)
    return int(sum(x.size * x.dtype.itemsize for x in leaves))


def make_forward(name, params, cfg: ModelConfig, use_pallas=True):
    """Bind params + config into the (deltas, pcs, hint) -> logits fn that
    aot.py lowers; params become HLO constants."""
    _, fwd = MODELS[name]
    return functools.partial(fwd, params, cfg, use_pallas=use_pallas)
