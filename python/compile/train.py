"""Pretraining loop for the address predictors (build-time only).

Runs at ``make artifacts`` before AOT export: each model is trained with a
hand-rolled Adam (no optax in this image) on the synthetic trace families
in traces.py, then its trained params are handed to aot.py to be baked
into the exported HLO as constants.

Training uses the pure-jnp attention reference (use_pallas=False) because
interpret-mode Pallas under autodiff is an order of magnitude slower; the
export path switches to the Pallas kernel, and test_model.py pins the two
paths to identical logits.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import config as C
from .model import MODELS
from .traces import sample_batch

# --------------------------------------------------------------------------
# Hand-rolled Adam (tree-based)
# --------------------------------------------------------------------------


def adam_init(params):
    zeros = lambda t: jax.tree_util.tree_map(jnp.zeros_like, t)
    return {"m": zeros(params), "v": zeros(params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    mhat_scale = 1.0 / (1 - b1 ** tf)
    vhat_scale = 1.0 / (1 - b2 ** tf)
    new = jax.tree_util.tree_map(
        lambda p, mi, vi: p - lr * (mi * mhat_scale) / (jnp.sqrt(vi * vhat_scale) + eps),
        params, m, v,
    )
    return new, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# Loss / accuracy
# --------------------------------------------------------------------------


def _loss_fn(fwd, cfg, params, deltas, pcs, hint, targets):
    logits = fwd(params, cfg, deltas, pcs, hint, use_pallas=False)  # [B,K,V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, cfg.delta_vocab)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def _accuracy(logits, targets):
    """Top-1 accuracy of the first-offset head (paper's 'accuracy')."""
    pred = jnp.argmax(logits[:, 0], axis=-1)
    return jnp.mean((pred == targets[:, 0]).astype(jnp.float32))


def _accuracy_all(logits, targets):
    """Top-1 accuracy averaged over all K prediction offsets."""
    pred = jnp.argmax(logits, axis=-1)
    return jnp.mean((pred == targets).astype(jnp.float32))


# --------------------------------------------------------------------------
# Training driver
# --------------------------------------------------------------------------


def train_model(name, cfg=C.EXPORT, steps=C.TRAIN_STEPS, batch=C.TRAIN_BATCH,
                lr=C.LEARNING_RATE, seed=C.SEED, log_every=200, verbose=True):
    """Train one model; returns (params, metrics dict)."""
    init, fwd = MODELS[name]
    key = jax.random.PRNGKey(seed + hash(name) % 1000)
    params = init(key, cfg)
    opt = adam_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step_fn(params, opt, lr_t, deltas, pcs, hint, targets):
        loss, grads = jax.value_and_grad(
            lambda p: _loss_fn(fwd, cfg, p, deltas, pcs, hint, targets)
        )(params)
        params, opt = adam_update(params, grads, opt, lr_t)
        return params, opt, loss

    t0 = time.time()
    for i in range(steps):
        # Linear warmup (5%) then cosine decay to 10% of peak.
        warm = min(1.0, i / max(1, steps // 20))
        cos = 0.1 + 0.9 * 0.5 * (1 + np.cos(np.pi * i / steps))
        lr_t = np.float32(lr * warm * cos)
        d, p, h, t = sample_batch(rng, batch, cfg.window, cfg.n_future)
        params, opt, loss = step_fn(params, opt, lr_t, d, p, h, t)
        if verbose and (i % log_every == 0 or i == steps - 1):
            print(f"[train:{name}] step {i:4d} loss {float(loss):.4f}")

    # Held-out evaluation on fresh samples.
    @jax.jit
    def eval_fn(params, deltas, pcs, hint):
        return fwd(params, cfg, deltas, pcs, hint, use_pallas=False)

    accs, accs_all = [], []
    for _ in range(C.EVAL_BATCHES):
        d, p, h, t = sample_batch(rng, batch, cfg.window, cfg.n_future)
        logits = eval_fn(params, d, p, h)
        accs.append(float(_accuracy(logits, t)))
        accs_all.append(float(_accuracy_all(logits, t)))
    metrics = {
        "model": name,
        "steps": steps,
        "train_seconds": round(time.time() - t0, 1),
        "eval_acc_top1": round(float(np.mean(accs)), 4),
        "eval_acc_allk": round(float(np.mean(accs_all)), 4),
    }
    if verbose:
        print(f"[train:{name}] held-out acc@1 {metrics['eval_acc_top1']:.3f} "
              f"acc@allK {metrics['eval_acc_allk']:.3f} "
              f"({metrics['train_seconds']}s)")
    return params, metrics
