//! Topology sweep: enumerate fabrics of increasing depth, show the
//! reflector's end-to-end latency calculation per level, and measure the
//! resulting application slowdown with and without topology-aware
//! timeliness (the paper's central ablation).
//!
//! Run: `cargo run --release --example topology_sweep`

use expand_cxl::config::{PrefetcherKind, SimConfig};
use expand_cxl::cxl::configspace::ConfigSpace;
use expand_cxl::cxl::enumeration::Enumeration;
use expand_cxl::cxl::{Fabric, Topology};
use expand_cxl::expand::timeliness::setup_device;
use expand_cxl::runtime::Runtime;
use expand_cxl::sim::runner::simulate;
use expand_cxl::ssd::CxlSsd;
use expand_cxl::workloads::WorkloadId;

fn main() -> anyhow::Result<()> {
    let base_cfg = SimConfig::default();

    println!("-- enumeration-time timeliness setup per switch depth --");
    println!("{:>6} {:>12} {:>12} {:>12}", "depth", "device_ns", "vh_ns", "e2e_ns");
    for levels in 0..=4 {
        let topo = Topology::chain(levels);
        let dev = topo.ssds()[0];
        let e = Enumeration::discover(&topo);
        let fabric = Fabric::new(topo, &base_cfg.cxl);
        let ssd = CxlSsd::new(&base_cfg.ssd);
        let mut cs = ConfigSpace::endpoint(1);
        let t = setup_device(&fabric, &e, &ssd, dev, &mut cs);
        println!(
            "{:>6} {:>12.1} {:>12.1} {:>12.1}",
            t.switch_depth,
            t.device_ps as f64 / 1000.0,
            t.vh_ps as f64 / 1000.0,
            t.e2e_ps as f64 / 1000.0
        );
    }

    println!("\n-- TC slowdown vs switch depth (ExPAND, topology-aware vs not) --");
    let runtime = if Runtime::artifacts_available("artifacts") {
        Some(Runtime::new("artifacts")?)
    } else {
        None
    };
    println!("{:>6} {:>14} {:>14}", "depth", "aware_ms", "unaware_ms");
    for levels in 1..=4 {
        let mut run = |aware: bool| -> anyhow::Result<f64> {
            let mut cfg = SimConfig::default();
            cfg.hierarchy.llc.size_bytes = 4 << 20;
            cfg.ssd.internal_dram_bytes = 8 << 20;
            cfg.accesses = 200_000;
            cfg.prefetcher = PrefetcherKind::Expand;
            cfg.cxl.switch_levels = levels;
            // "Unaware": the decider believes the device is directly
            // attached (timeliness model ignores switch latency).
            cfg.expand.timeliness_accuracy = if aware { 1.0 } else { 0.0 };
            let mut src = WorkloadId::Tc.source(cfg.seed);
            Ok(simulate(&std::sync::Arc::new(cfg), runtime.as_ref(), &mut *src)?.exec_ps
                as f64
                / 1e9)
        };
        println!("{:>6} {:>14.2} {:>14.2}", levels, run(true)?, run(false)?);
    }
    Ok(())
}
