//! Quickstart: simulate the TC graph workload on a CXL-SSD behind one
//! switch, with and without ExPAND, and print the speedup.
//!
//! Run: `cargo run --release --example quickstart`
//! (artifacts optional — falls back to the mock predictor without them).

use expand_cxl::config::{PrefetcherKind, SimConfig};
use expand_cxl::runtime::Runtime;
use expand_cxl::sim::runner::simulate;
use expand_cxl::workloads::WorkloadId;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // A scaled configuration: 4 MB LLC against a ~30 MB working set.
    let mut cfg = SimConfig::default();
    cfg.hierarchy.llc.size_bytes = 4 << 20;
    cfg.ssd.internal_dram_bytes = 8 << 20;
    cfg.accesses = 300_000;

    let runtime = if Runtime::artifacts_available(&cfg.artifacts_dir) {
        Some(Runtime::new(&cfg.artifacts_dir)?)
    } else {
        eprintln!("note: no artifacts found; using mock predictor (run `make artifacts`)");
        None
    };

    // Baseline: CXL-SSD without prefetching. Each variant is its own
    // immutable shared config (`simulate` takes `&Arc<SimConfig>`).
    cfg.prefetcher = PrefetcherKind::None;
    let cfg_base = Arc::new(cfg.clone());
    let mut src = WorkloadId::Tc.source(cfg_base.seed);
    let base = simulate(&cfg_base, runtime.as_ref(), &mut *src)?;
    println!("{}", base.summary());

    // ExPAND: expander-driven prefetching.
    cfg.prefetcher = PrefetcherKind::Expand;
    let cfg = Arc::new(cfg);
    let mut src = WorkloadId::Tc.source(cfg.seed);
    let ex = simulate(&cfg, runtime.as_ref(), &mut *src)?;
    println!("{}", ex.summary());

    println!(
        "\nExPAND speedup over NoPrefetch: {:.2}x (LLC hit {:.1}% -> {:.1}%)",
        ex.speedup_over(&base),
        base.llc_hit_ratio() * 100.0,
        ex.llc_hit_ratio() * 100.0
    );
    Ok(())
}
