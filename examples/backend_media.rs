//! Backend-media comparison (Fig 7 scenario): run the same workload on
//! ExPAND-Z (Z-NAND), ExPAND-P (PMEM) and ExPAND-D (DRAM) expanders and
//! compare against the LocalDRAM baseline.
//!
//! Run: `cargo run --release --example backend_media [workload]`

use expand_cxl::config::{Backing, MediaKind, PrefetcherKind, SimConfig, SsdConfig};
use expand_cxl::runtime::Runtime;
use expand_cxl::sim::runner::simulate;
use expand_cxl::workloads::WorkloadId;

fn main() -> anyhow::Result<()> {
    let workload = std::env::args().nth(1).unwrap_or_else(|| "leslie3d".to_string());
    let id = WorkloadId::parse(&workload)?;
    let runtime = if Runtime::artifacts_available("artifacts") {
        Some(Runtime::new("artifacts")?)
    } else {
        eprintln!("note: mock predictor (run `make artifacts`)");
        None
    };

    let base_cfg = || {
        let mut c = SimConfig::default();
        c.hierarchy.llc.size_bytes = 4 << 20;
        c.ssd.internal_dram_bytes = 8 << 20;
        c.accesses = 300_000;
        c
    };

    // LocalDRAM baseline.
    let mut cfg = base_cfg();
    cfg.backing = Backing::LocalDram;
    let mut src = id.source(cfg.seed);
    let local = simulate(&std::sync::Arc::new(cfg), runtime.as_ref(), &mut *src)?;
    println!("{:<10} exec={:>10.2}ms  (baseline)", "LocalDRAM", local.exec_ps as f64 / 1e9);

    for media in [MediaKind::ZNand, MediaKind::Pmem, MediaKind::Dram] {
        let mut cfg = base_cfg();
        let internal = cfg.ssd.internal_dram_bytes;
        cfg.ssd = SsdConfig::with_media(media);
        cfg.ssd.internal_dram_bytes = internal;
        cfg.prefetcher = PrefetcherKind::Expand;
        let mut src = id.source(cfg.seed);
        let s = simulate(&std::sync::Arc::new(cfg), runtime.as_ref(), &mut *src)?;
        println!(
            "{:<10} exec={:>10.2}ms  vs LocalDRAM {:>6.2}x  LLC-hit {:>5.1}%  ssd-internal-hit {:>5.1}%",
            format!("ExPAND-{}", media.name().chars().next().unwrap().to_uppercase()),
            s.exec_ps as f64 / 1e9,
            s.speedup_over(&local),
            s.llc_hit_ratio() * 100.0,
            s.ssd_internal_hit * 100.0,
        );
    }
    Ok(())
}
