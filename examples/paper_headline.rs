//! End-to-end driver (EXPERIMENTS.md §End-to-end): run every graph and
//! SPEC workload through the full stack — Rust coordinator, CXL fabric,
//! CXL-SSD model, and the AOT-compiled multi-modality transformer on the
//! decider's hot path — and report the paper's headline metric: mean
//! speedup of ExPAND over NoPrefetch for graph and SPEC suites (paper:
//! 9.0x graphs, 14.7x SPEC), plus per-workload rows.
//!
//! Run: `make artifacts && cargo run --release --example paper_headline`

use expand_cxl::config::PrefetcherKind;
use expand_cxl::figures::{figure_config, FigOpts};
use expand_cxl::runtime::Runtime;
use expand_cxl::sim::runner::simulate;
use expand_cxl::util::stats::geomean;
use expand_cxl::workloads::WorkloadId;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let opts = FigOpts { accesses: 400_000, ..Default::default() };
    let runtime = match &opts.artifacts {
        Some(dir) if Runtime::artifacts_available(dir) => Some(Runtime::new(dir)?),
        _ => {
            eprintln!("note: running with mock predictor (run `make artifacts` for the real one)");
            None
        }
    };

    println!("{:<12} {:>14} {:>14} {:>9} {:>10} {:>10}",
        "workload", "noprefetch", "expand", "speedup", "hit-before", "hit-after");
    let mut graph_speedups = Vec::new();
    let mut spec_speedups = Vec::new();
    for id in WorkloadId::ALL {
        let mut cfg = figure_config(&opts);
        cfg.prefetcher = PrefetcherKind::None;
        let cfg_base = Arc::new(cfg.clone());
        let mut src = id.source(cfg_base.seed);
        let base = simulate(&cfg_base, runtime.as_ref(), &mut *src)?;

        cfg.prefetcher = PrefetcherKind::Expand;
        let cfg = Arc::new(cfg);
        let mut src = id.source(cfg.seed);
        let ex = simulate(&cfg, runtime.as_ref(), &mut *src)?;

        let s = ex.speedup_over(&base);
        println!(
            "{:<12} {:>12.2}ms {:>12.2}ms {:>8.2}x {:>9.1}% {:>9.1}%",
            id.name(),
            base.exec_ps as f64 / 1e9,
            ex.exec_ps as f64 / 1e9,
            s,
            base.llc_hit_ratio() * 100.0,
            ex.llc_hit_ratio() * 100.0
        );
        if id.is_graph() {
            graph_speedups.push(s);
        } else {
            spec_speedups.push(s);
        }
    }
    println!(
        "\nHEADLINE  graph mean speedup: {:.2}x   SPEC mean speedup: {:.2}x",
        geomean(&graph_speedups),
        geomean(&spec_speedups)
    );
    println!("(paper reports 9.0x graphs / 14.7x SPEC vs prefetching baselines)");
    Ok(())
}
