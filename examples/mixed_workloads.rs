//! Mixed-workload scenario (Fig 4b): distinct workloads interleaved on
//! the cores, which intertwines the miss streams. Single-stream
//! prefetchers (Rule1, ML without PC modality) collapse; ExPAND's
//! PC-aware multi-modality predictor keeps the streams separable.
//!
//! Run: `cargo run --release --example mixed_workloads`

use expand_cxl::config::PrefetcherKind;
use expand_cxl::figures::{figure_config, FigOpts};
use expand_cxl::runtime::Runtime;
use expand_cxl::sim::runner::simulate;
use expand_cxl::workloads::mixed::MixedTrace;
use expand_cxl::workloads::WorkloadId;

fn main() -> anyhow::Result<()> {
    let opts = FigOpts { accesses: 300_000, ..Default::default() };
    let runtime = match &opts.artifacts {
        Some(d) if Runtime::artifacts_available(d) => Some(Runtime::new(d)?),
        _ => None,
    };
    let mix = [WorkloadId::Cc, WorkloadId::Tc];

    let mut cfg = figure_config(&opts);
    cfg.prefetcher = PrefetcherKind::None;
    let mut src = MixedTrace::new(&mix, cfg.seed);
    let base = simulate(&std::sync::Arc::new(cfg), runtime.as_ref(), &mut src)?;
    println!("{}", base.summary());

    for kind in [
        PrefetcherKind::Rule1,
        PrefetcherKind::Rule2,
        PrefetcherKind::Ml1,
        PrefetcherKind::Ml2,
        PrefetcherKind::Expand,
    ] {
        let mut cfg = figure_config(&opts);
        cfg.prefetcher = kind;
        let mut src = MixedTrace::new(&mix, cfg.seed);
        let s = simulate(&std::sync::Arc::new(cfg), runtime.as_ref(), &mut src)?;
        println!("{}   speedup {:.2}x", s.summary(), s.speedup_over(&base));
    }
    Ok(())
}
